"""Mesh-invariance checker: the sharded round must not change numerics.

For EVERY registered algorithm this driver runs the same padded rounds
(fixed capacity, varying live cohort sizes) three ways and compares:

  base   — the classic unsharded jitted round,
  mesh1  — a 1-device (1, 1) mesh: must match ``base`` BIT-FOR-BIT
           (sharding constraints pin layout, never values),
  meshN  — a forced N-device host mesh (N, 1) over ('data', 'model'):
           must match within float tolerance (cross-device psum
           reduction trees reorder float32 sums at ~1e-7) and must
           trace exactly ONCE across the varying cohort sizes.

Run as a subprocess so the forced host device count binds before jax
initializes (tests/test_mesh.py drives it this way; CI runs the whole
tier-1 suite under the same flag):

  PYTHONPATH=src python -m repro.launch.meshcheck --devices 8

Exit code 0 = every algorithm passed; the JSON report goes to stdout.
"""
import os
import sys


def _cli_devices(argv) -> int:
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 8


if __name__ == "__main__":
    # must bind before the jax import below — jax locks the device count
    # at first initialization (same trick as launch/dryrun.py); appended
    # so inherited XLA flags survive (last device-count occurrence wins)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        " --xla_force_host_platform_device_count="
        f"{_cli_devices(sys.argv[1:])}").strip()

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PROGRAMS, build_algorithm, get_program
from repro.api.phases import build_pipelined_algorithm
from repro.core.cyclesl import CycleConfig
from repro.core.split import make_stage_task
from repro.models.cnn import mlp
from repro.optim import adam
from repro.sharding.specs import batch_spec, train_state_shardings

C, B, ROUNDS = 8, 8, 3          # capacity 8 divides every swept mesh


def _task_and_data():
    task = make_stage_task(mlp(8, [16], 4), cut=1, kind="xent")
    rng = np.random.default_rng(0)
    w = rng.normal(size=(8, 4))
    xs = np.stack([rng.normal(size=(B, 8))
                   for _ in range(C)]).astype(np.float32)
    ys = np.argmax(xs @ w, axis=-1)
    return task, jnp.asarray(xs), jnp.asarray(ys)


def _masks(rounds: int = ROUNDS):
    """Varying live cohort sizes at fixed capacity (the compile-once
    stream the Engine produces under variable attendance)."""
    return [jnp.asarray((np.arange(C) < 5 + r % 3).astype(np.float32))
            for r in range(rounds)]


def _place(x, mesh):
    from jax.sharding import NamedSharding
    return jax.device_put(
        x, NamedSharding(mesh, batch_spec(mesh, x.shape[0], x.ndim - 1)))


def _drive(name, task, xs, ys, mesh=None, rounds: int = ROUNDS,
           shard_local: bool = False, pipelined: bool = False):
    """Run ``rounds`` padded rounds of one algorithm (optionally on a
    mesh with full TrainState/input placement) and return
    ``(state, metric rows, trace count)``.  tests/test_mesh.py reuses
    this so the in-process goldens and this subprocess checker drive the
    exact same protocol.

    ``shard_local`` turns on ``CycleConfig.shard_local_resample`` (the
    shard_map resample path); ``pipelined`` drives the (extract, tail)
    dispatch pair in sync-barrier order instead of the monolithic round
    (returns ``None`` for the fused sequential programs, which have no
    ExtractFeatures head to split on)."""
    opt = adam(5e-3)
    program = get_program(name)
    kw = {}
    if mesh is not None:
        a_state = jax.eval_shape(
            lambda: build_algorithm(program, task, opt, opt).init(
                jax.random.PRNGKey(0), C))
        kw = dict(mesh=mesh,
                  state_shardings=train_state_shardings(a_state, mesh))
    ccfg = CycleConfig(server_epochs=2, shard_local_resample=shard_local)
    if pipelined:
        algo = build_pipelined_algorithm(program, task, opt, opt, ccfg, **kw)
        if algo is None:
            return None
    else:
        algo = build_algorithm(program, task, opt, opt, ccfg, **kw)
    state = algo.init(jax.random.PRNGKey(0), n_clients=C)
    cohort = jnp.arange(C)
    if mesh is not None:
        state = jax.device_put(state, kw["state_shardings"])
        cohort, xs, ys = (_place(v, mesh) for v in (cohort, xs, ys))
    rows = []
    for r, mask in enumerate(_masks(rounds)):
        m = _place(mask, mesh) if mesh is not None else mask
        if pipelined:
            stage = algo.extract(state, cohort, xs, ys, m)
            state, mets = algo.tail(state, cohort, xs, ys,
                                    jax.random.PRNGKey(r), stage, m)
        else:
            state, mets = algo.round(state, cohort, xs, ys,
                                     jax.random.PRNGKey(r), m)
        rows.append({k: np.asarray(v) for k, v in mets.items()})
    return state, rows, algo.trace_count


def _max_diff(a_state, a_rows, b_state, b_rows) -> float:
    d = 0.0
    for la, lb in zip(jax.tree.leaves(a_state), jax.tree.leaves(b_state)):
        d = max(d, float(np.max(np.abs(np.asarray(la, np.float64)
                                       - np.asarray(lb, np.float64)))))
    for ra, rb in zip(a_rows, b_rows):
        for k in ra:
            d = max(d, float(np.max(np.abs(ra[k].astype(np.float64)
                                           - rb[k].astype(np.float64)))))
    return d


def check_algorithm(name, task, xs, ys, meshN, tol: float) -> dict:
    base_state, base_rows, _ = _drive(name, task, xs, ys)
    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    s1, r1, _ = _drive(name, task, xs, ys, mesh1)
    sN, rN, traces = _drive(name, task, xs, ys, meshN)
    d1 = _max_diff(base_state, base_rows, s1, r1)
    dN = _max_diff(base_state, base_rows, sN, rN)
    rec = {"exact_1dev_diff": d1, "ndev_diff": dN, "ndev_traces": traces,
           "ok": bool(d1 == 0.0 and dN <= tol and traces == 1)}
    return rec


def check_shard_local(name, task, xs, ys, meshes) -> dict:
    """The shard-local acceptance golden: on every mesh, for both the
    monolithic round and the pipelined (extract, tail) schedule, the
    ``shard_local_resample`` path must be BIT-FOR-BIT the GSPMD
    gather-around-the-kernel path and still trace once per dispatch
    (the shard_map wrapper must not retrace across live cohort sizes).
    Non-cycle algorithms never touch the resample, so their equality is
    trivially exact — running them all pins that the knob is inert
    where it should be."""
    rec = {"ok": True}
    for label, mesh in meshes:
        for pipelined in (False, True):
            base = _drive(name, task, xs, ys, mesh, shard_local=False,
                          pipelined=pipelined)
            if base is None:        # fused sequential program: no split
                continue
            on = _drive(name, task, xs, ys, mesh, shard_local=True,
                        pipelined=pipelined)
            d = _max_diff(base[0], base[1], on[0], on[1])
            traces = on[2]
            budget = 2 if pipelined else 1
            key = f"{label}{'_pipelined' if pipelined else ''}"
            rec[key] = {"diff": d, "traces": traces}
            rec["ok"] = rec["ok"] and d == 0.0 and traces == budget
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--algos", default=None,
                    help="comma list (default: every registered algorithm)")
    ap.add_argument("--tol", type=float, default=1e-5,
                    help="max abs diff tolerated for the N-device mesh "
                         "(cross-device reduction reorder noise)")
    ap.add_argument("--shard-local", action="store_true",
                    help="run the shard-local-vs-GSPMD resample golden "
                         "instead of the sharded-vs-unsharded sweep")
    args = ap.parse_args()
    n = args.devices
    if jax.device_count() < n:
        print(json.dumps({"error": f"needs {n} devices, have "
                          f"{jax.device_count()} (run via python -m, the "
                          "__main__ guard forces the host device count)"}))
        return 2
    meshN = jax.make_mesh((n, 1), ("data", "model"),
                          devices=jax.devices()[:n])
    task, xs, ys = _task_and_data()
    algos = (args.algos.split(",") if args.algos else sorted(PROGRAMS))
    report = {"devices": n, "capacity": C, "rounds": ROUNDS, "algos": {}}
    if args.shard_local:
        mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                              devices=jax.devices()[:1])
        meshes = [("1dev", mesh1), (f"{n}dev", meshN)]
        report["mode"] = "shard_local"
        for name in algos:
            report["algos"][name] = check_shard_local(name, task, xs, ys,
                                                      meshes)
    else:
        for name in algos:
            report["algos"][name] = check_algorithm(name, task, xs, ys,
                                                    meshN, args.tol)
    report["ok"] = all(a["ok"] for a in report["algos"].values())
    print(json.dumps(report, indent=1))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
