"""ShapeDtypeStruct stand-ins for every model input (dry-run inputs).

Nothing here allocates device memory: ``input_specs`` returns abstract
values that ``jax.jit(...).lower()`` consumes directly.

Modality stubs (assignment carve-out):
  * vlm   — ``patch_embeds`` [B, n_patch_tokens, d] precomputed patch
            embeddings (vision encoder + projector stubbed).
  * audio — ``frames`` [B, 1500, d] precomputed conv/mel frame
            embeddings (whisper frontend stubbed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape

WHISPER_FRAMES = 1500
WHISPER_TEXT_CAP = 448      # whisper decoder positional horizon


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ArchConfig, shape: InputShape, cohort: int):
    """(xs, ys) cohort-stacked batch specs [C, b, ...] for the CycleSL
    train step."""
    assert shape.global_batch % cohort == 0, (shape.global_batch, cohort)
    b = shape.global_batch // cohort
    if cfg.family == "audio":
        s = min(shape.seq_len, WHISPER_TEXT_CAP)
        xs = {"frames": sds((cohort, b, WHISPER_FRAMES, cfg.enc_d_model),
                            cfg.jnp_dtype)}
        ys = {"tokens": sds((cohort, b, s), jnp.int32),
              "labels": sds((cohort, b, s), jnp.int32)}
        return xs, ys
    xs = {"tokens": sds((cohort, b, shape.seq_len), jnp.int32)}
    if cfg.family == "vlm":
        xs["patch_embeds"] = sds(
            (cohort, b, cfg.n_patch_tokens, cfg.d_model), cfg.jnp_dtype)
    ys = sds((cohort, b, shape.seq_len), jnp.int32)
    return xs, ys


def prefill_specs(cfg: ArchConfig, shape: InputShape):
    B = shape.global_batch
    if cfg.family == "audio":
        s = min(shape.seq_len, WHISPER_TEXT_CAP)
        return {"frames": sds((B, WHISPER_FRAMES, cfg.enc_d_model), cfg.jnp_dtype),
                "tokens": sds((B, s), jnp.int32)}
    out = {"tokens": sds((B, shape.seq_len), jnp.int32)}
    if cfg.family == "vlm":
        out["patch_embeds"] = sds((B, cfg.n_patch_tokens, cfg.d_model),
                                  cfg.jnp_dtype)
    return out


def decode_token_spec(cfg: ArchConfig, shape: InputShape):
    return sds((shape.global_batch, 1), jnp.int32)
