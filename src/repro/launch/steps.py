"""Step-function builders for the dry-run / launcher.

For every (arch × input shape) we lower one of:

  train  — one full CycleSL round (paper Algorithm 1) over a cohort of
           ``data``(×``pod``)-resident clients: the paper's technique IS
           the train step, not an afterthought.
  prefill— composed-model forward, next-token logits.
  decode — one-token serve step against a KV/SSM cache of seq_len.

Each builder returns a :class:`StepBundle` with abstract inputs and
matching NamedShardings, ready for ``jit(...).lower(...)``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core.cyclesl import (CycleConfig, cyclesl_extract, cyclesl_round,
                                cyclesl_tail)
from repro.core.protocol import EntityState, init_entity
from repro.core.split import SplitTask, make_transformer_task, xent_loss, xent_metrics
from repro.launch import inputs as inputs_lib
from repro.launch.mesh import batch_axes, cohort_size
from repro.models.encdec import EncDec
from repro.models.transformer import Transformer
from repro.optim import adam
from repro.sharding.specs import (param_specs, set_activation_mesh,
                                  shard_if_divisible)
from repro.utils.tree import map_with_path


@dataclass
class StepBundle:
    name: str
    fn: Callable
    abstract_args: tuple
    in_shardings: Any
    out_shardings: Any
    # arg indices donated to XLA (in-place state/cache updates; without
    # this the decode KV cache exists 2-3x per step — §Perf iteration)
    donate: tuple = ()


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def _batch_leading_spec(mesh, leaf_shape, extra: int):
    axes = batch_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    if not axes or leaf_shape[0] % size != 0:
        lead = None
    return P(lead, *([None] * extra))


def _batch_lead(mesh):
    """Leaf -> NamedSharding with the leading dim on the batch axes —
    the one shard rule every cohort/stage/input tensor uses."""
    return lambda l: NamedSharding(
        mesh, _batch_leading_spec(mesh, l.shape, len(l.shape) - 1))


# ------------------------------------------------------------ whisper task
def make_whisper_task(cfg: ArchConfig) -> SplitTask:
    """Whisper SplitTask: encoder = client, decoder = server."""

    def init_client(key):
        return EncDec.init(key, cfg)["encoder"]

    def init_server(key):
        return EncDec.init(key, cfg)["decoder"]

    def client_forward(cp, batch):
        return EncDec.encode(cp, cfg, batch["frames"])

    def server_apply(sp, feats_and_tokens):
        # server consumes (enc_out, tokens); tokens ride in the label tree
        raise NotImplementedError  # replaced below by closure trick

    def server_loss(sp, features, y):
        logits = EncDec.decode_train(sp, cfg, y["tokens"], features)
        return xent_loss(logits, y["labels"])

    task = SplitTask(f"{cfg.name}@encdec", init_client, init_server,
                     client_forward, server_apply,
                     lambda out, y: out, lambda out, y: {})
    # server_loss is the only server entry point the algorithms use for
    # whisper; patch it in (SplitTask is frozen -> build a subclass-free
    # copy via object.__setattr__)
    object.__setattr__(task, "server_loss", server_loss)
    return task


# ------------------------------------------------------------- train step
@dataclass
class _TrainSubstrate:
    """Task, optimizers, abstract train state/batches and their
    shardings — the construction shared by the monolithic and pipelined
    train-step builders (one source, so they cannot drift)."""
    task: SplitTask
    opt_s: Any
    opt_c: Any
    a_server: Any
    a_clients: Any
    xs: Any
    ys: Any
    a_key: Any
    s_server: Any
    s_clients: Any
    s_xs: Any
    s_ys: Any
    s_key: Any


def _train_substrate(cfg: ArchConfig, mesh, shape: InputShape
                     ) -> _TrainSubstrate:
    cohort = cohort_size(mesh)
    task = (make_whisper_task(cfg) if cfg.family == "audio"
            else make_transformer_task(cfg))
    opt_s, opt_c = adam(3e-4), adam(3e-4)
    a_server = jax.eval_shape(
        lambda: init_entity(task.init_server(jax.random.PRNGKey(0)), opt_s))
    a_client1 = jax.eval_shape(
        lambda: init_entity(task.init_client(jax.random.PRNGKey(0)), opt_c))
    a_clients = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((cohort,) + l.shape, l.dtype),
        a_client1)
    xs, ys = inputs_lib.train_batch_specs(cfg, shape, cohort)
    a_key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    moe_mode = cfg.moe.shard_mode if cfg.moe else "expert"
    s_server = _ns(mesh, param_specs(a_server, mesh, "server", moe_mode))
    s_clients = _ns(mesh, param_specs(a_clients, mesh, "client", moe_mode))
    s_xs = jax.tree.map(_batch_lead(mesh), xs)
    s_ys = jax.tree.map(_batch_lead(mesh), ys)
    return _TrainSubstrate(task, opt_s, opt_c, a_server, a_clients, xs, ys,
                           a_key, s_server, s_clients, s_xs, s_ys,
                           NamedSharding(mesh, P()))


def build_train_step(cfg: ArchConfig, mesh, shape: InputShape,
                     cycle: CycleConfig = CycleConfig()) -> StepBundle:
    sub = _train_substrate(cfg, mesh, shape)
    task, opt_s, opt_c = sub.task, sub.opt_s, sub.opt_c

    # the resampled server minibatches stay data-parallel on the pod via
    # sharding.specs.constrain_server_batch (perf iteration 3), threaded
    # through cyclesl_round's mesh argument — the old un-serializable
    # CycleConfig.batch_constraint callable hook is gone.
    def train_step(server, clients, xs, ys, key):
        return cyclesl_round(task, server, clients, opt_s, opt_c,
                             xs, ys, key, cycle, mesh=mesh)

    a_metrics = jax.eval_shape(train_step, sub.a_server, sub.a_clients,
                               sub.xs, sub.ys, sub.a_key)[2]
    out_shardings = (sub.s_server, sub.s_clients, _replicated(mesh, a_metrics))
    return StepBundle(
        "train", train_step,
        (sub.a_server, sub.a_clients, sub.xs, sub.ys, sub.a_key),
        (sub.s_server, sub.s_clients, sub.s_xs, sub.s_ys, sub.s_key),
        out_shardings, donate=(0, 1))


def build_pipelined_train_steps(cfg: ArchConfig, mesh, shape: InputShape,
                                cycle: CycleConfig = CycleConfig()
                                ) -> tuple[StepBundle, StepBundle]:
    """The CycleSL round as TWO overlappable dispatches (train_extract,
    train_tail) — the launcher-side mirror of the Engine's pipelined
    schedule: extraction for cohort k+1 is lowered against the batch
    axes only, the tail against the server weight axes plus the stage
    handoff, so the compiler can run them concurrently.

    ``train_extract(clients, xs, ys) -> (feats, store)`` and
    ``train_tail(server, clients, xs, ys, key, feats, store)`` compose
    to exactly :func:`build_train_step`'s monolithic round.
    """
    sub = _train_substrate(cfg, mesh, shape)
    task, opt_s, opt_c = sub.task, sub.opt_s, sub.opt_c

    def extract_step(clients, xs, ys):
        return cyclesl_extract(task, clients, xs, ys, mesh=mesh)

    def tail_step(server, clients, xs, ys, key, feats, store):
        return cyclesl_tail(task, server, clients, opt_s, opt_c, xs, ys,
                            key, cycle, feats, store, mesh=mesh)

    a_feats, a_store = jax.eval_shape(extract_step, sub.a_clients, sub.xs,
                                      sub.ys)
    # stage tensors are batch-leading (feats cohort dim, store rows)
    s_feats = jax.tree.map(_batch_lead(mesh), a_feats)
    s_store = jax.tree.map(_batch_lead(mesh), a_store)

    extract_bundle = StepBundle(
        "train_extract", extract_step, (sub.a_clients, sub.xs, sub.ys),
        (sub.s_clients, sub.s_xs, sub.s_ys), (s_feats, s_store))
    a_metrics = jax.eval_shape(tail_step, sub.a_server, sub.a_clients,
                               sub.xs, sub.ys, sub.a_key, a_feats,
                               a_store)[2]
    tail_bundle = StepBundle(
        "train_tail", tail_step,
        (sub.a_server, sub.a_clients, sub.xs, sub.ys, sub.a_key, a_feats,
         a_store),
        (sub.s_server, sub.s_clients, sub.s_xs, sub.s_ys, sub.s_key,
         s_feats, s_store),
        (sub.s_server, sub.s_clients, _replicated(mesh, a_metrics)),
        donate=(0, 1, 5, 6))          # state + the consumed stage buffers
    return extract_bundle, tail_bundle


# ----------------------------------------------------------- prefill step
def build_prefill_step(cfg: ArchConfig, mesh, shape: InputShape,
                       long_context: bool = False) -> StepBundle:
    if cfg.family == "audio":
        def prefill(params, batch):
            logits = EncDec.forward(params, cfg, batch["frames"], batch["tokens"])
            return logits[:, -1].astype(jnp.bfloat16)
        a_params = jax.eval_shape(lambda: EncDec.init(jax.random.PRNGKey(0), cfg))
    else:
        def prefill(params, batch):
            logits, _ = Transformer.forward(
                params, cfg, batch["tokens"], batch.get("patch_embeds"),
                long_context=long_context)
            return logits[:, -1].astype(jnp.bfloat16)
        a_params = jax.eval_shape(
            lambda: Transformer.init(jax.random.PRNGKey(0), cfg))

    batch = inputs_lib.prefill_specs(cfg, shape)
    moe_mode = cfg.moe.shard_mode if cfg.moe else "expert"
    s_params = _ns(mesh, param_specs(a_params, mesh, "full", moe_mode))
    s_batch = jax.tree.map(
        lambda l: NamedSharding(mesh, _batch_leading_spec(mesh, l.shape,
                                                          len(l.shape) - 1)),
        batch)
    out_sh = NamedSharding(mesh, _batch_leading_spec(
        mesh, (shape.global_batch,), 1))
    return StepBundle("prefill", prefill, (a_params, batch),
                      (s_params, s_batch), out_sh)


# ------------------------------------------------------------ decode step
_DECODE_RULES = [
    # suffix regex, callable(shape, mesh) -> PartitionSpec
    (r"kv/k$|kv/v$", "kvcache"),     # [L,B,C,Hkv,Dh]
    (r"mamba/h$", "mamba_h"),        # [L,B,H,N,P]
    (r"mamba/conv$", "mamba_conv"),  # [L,B,K-1,ch]
    (r"enc_out$", "enc_out"),        # [B,T,d]
]


def _decode_state_spec(path: str, leaf, mesh) -> P:
    shape = leaf.shape
    bspec = _batch_leading_spec(mesh, shape[1:2] if len(shape) > 1 else (1,), 0)
    batch_axis = bspec[0] if len(bspec) else None
    for pat, kind in _DECODE_RULES:
        if not re.search(pat, path):
            continue
        if kind == "kvcache":
            L, B, C, Hkv, Dh = shape
            h_ax = shard_if_divisible(Hkv, "model", mesh)
            c_ax = None if h_ax else shard_if_divisible(C, "model", mesh)
            b_ax = batch_axis if B > 1 else None
            if b_ax is None and batch_axis is None:
                # batch=1 long-context: shard cache length over 'data'
                c_data = shard_if_divisible(C, "data", mesh)
                return P(None, None, c_data, h_ax, None)
            return P(None, b_ax, c_ax, h_ax, None)
        if kind == "mamba_h":
            L, B, H, N, Pd = shape
            h_ax = shard_if_divisible(H, "model", mesh)
            return P(None, batch_axis if B > 1 else None, h_ax, None, None)
        if kind == "mamba_conv":
            L, B, K, ch = shape
            c_ax = shard_if_divisible(ch, "model", mesh)
            return P(None, batch_axis if B > 1 else None, None, c_ax)
        if kind == "enc_out":
            B, T, d = shape
            d_ax = shard_if_divisible(d, "model", mesh)
            return P(batch_axis if B > 1 else None, None, d_ax)
    return P()


def decode_state_shardings(a_state, mesh):
    """NamedSharding tree for a decode state (or serving slot table).

    Public wrapper over the `_DECODE_RULES` placement: any pytree whose
    leaf paths follow the decode-state naming (`kv/k`, `mamba/h`, ...)
    with the batch/slot axis in the batch position gets the exact
    shardings `build_decode_step` lowers — the serve runtime places its
    slot table with this so serving rides the same mesh substrate as
    training.
    """
    return _ns(mesh, map_with_path(
        lambda path, leaf: _decode_state_spec(path, leaf, mesh), a_state))


def build_decode_step(cfg: ArchConfig, mesh, shape: InputShape,
                      long_context: bool = False) -> StepBundle:
    B = shape.global_batch
    if cfg.family == "audio":
        a_params = jax.eval_shape(lambda: EncDec.init(jax.random.PRNGKey(0), cfg))

        def decode(params, token, state):
            return EncDec.decode_step(params, cfg, token, state,
                                      long_context=long_context)

        frames = inputs_lib.sds((B, inputs_lib.WHISPER_FRAMES, cfg.enc_d_model),
                                cfg.jnp_dtype)
        a_state = jax.eval_shape(
            lambda p, f: EncDec.init_decode_state(p, cfg, f, shape.seq_len,
                                                  long_context),
            a_params, frames)
    else:
        a_params = jax.eval_shape(
            lambda: Transformer.init(jax.random.PRNGKey(0), cfg))

        def decode(params, token, state):
            return Transformer.decode_step(params, cfg, token, state,
                                           long_context=long_context)

        a_state = jax.eval_shape(
            lambda: Transformer.init_decode_state(cfg, B, shape.seq_len,
                                                  long_context))

    token = inputs_lib.decode_token_spec(cfg, shape)
    moe_mode = cfg.moe.shard_mode if cfg.moe else "expert"
    s_params = _ns(mesh, param_specs(a_params, mesh, "full", moe_mode))
    s_state = decode_state_shardings(a_state, mesh)
    s_token = NamedSharding(mesh, _batch_leading_spec(mesh, token.shape, 1))
    a_out = jax.eval_shape(decode, a_params, token, a_state)
    out_sh = (NamedSharding(mesh, _batch_leading_spec(mesh, token.shape, 2)),
              s_state)
    del a_out
    return StepBundle("decode", decode, (a_params, token, a_state),
                      (s_params, s_token, s_state), out_sh, donate=(2,))


def build_step(cfg: ArchConfig, mesh, shape: InputShape,
               long_context: Optional[bool] = None,
               cycle: CycleConfig = CycleConfig()) -> StepBundle:
    set_activation_mesh(mesh)   # activation-batch constraints (§Perf it.5)
    lc = shape.name == "long_500k" if long_context is None else long_context
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, cycle)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, long_context=lc)
    return build_decode_step(cfg, mesh, shape, long_context=lc)
