"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Derives the three roofline terms per (arch × shape × mesh) from
``benchmarks/results/dryrun.json``:

    compute    = HLO_FLOPs_global    / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes_global    / (chips × 819e9  B/s HBM)
    collective = collective_bytes    / (chips × 50e9   B/s ICI per link)

Calibration notes (verified empirically in tests/test_roofline.py):
  * ``compiled.cost_analysis()`` on an SPMD-partitioned module reports
    *per-device* FLOPs/bytes, so globals = per-device × chips.
  * XLA counts a while/scan body ONCE regardless of trip count — fatal
    for scan-over-layers models.  The dry-run therefore records a
    loop-aware cost model (``repro.utils.hlo_cost``) that parses the
    optimized HLO, multiplies per-computation dot-FLOPs / HBM-boundary
    traffic / collective operand bytes by the product of enclosing
    ``known_trip_count``s, and is exact on nested-scan calibration
    cases.  Those numbers (also per-device) feed the terms below.

Also reports MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/dispatch waste).

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--in dryrun.json] [--md]
"""
from __future__ import annotations

import argparse
import json

from repro.configs import INPUT_SHAPES
from repro.configs.registry import get_config

PEAK_FLOPS = 197e12          # per chip, bf16
HBM_BW = 819e9               # per chip, bytes/s
ICI_BW = 50e9                # per link, bytes/s


def model_flops(arch: str, shape_name: str) -> float:
    """6·N·D for train (fwd+bwd), 2·N·D for inference; MoE uses N_active.
    whisper: the decoder horizon is 448 and the encoder runs over 1500
    stub frames, so effective tokens = B·(448 + 1500) (coarse)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.n_active_params()
    if cfg.family == "audio":
        tokens = shape.global_batch * (min(shape.seq_len, 448) + 1500)
    else:
        tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec.get("n_devices", 256)
    la = rec.get("loop_aware", {})
    flops_dev = la.get("flops") or rec.get("cost", {}).get("flops", 0.0)
    bytes_dev = (la.get("traffic_bytes")
                 or rec.get("cost", {}).get("bytes accessed", 0.0))
    # collective bytes: loop-aware number is per-device operand bytes
    coll_dev = (la.get("collective_bytes")
                or rec.get("collectives", {}).get("total_bytes", 0))
    flops_glob = flops_dev * chips
    bytes_glob = bytes_dev * chips
    t_compute = flops_glob / (chips * PEAK_FLOPS)
    t_memory = bytes_glob / (chips * HBM_BW)
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "hlo_flops_global": flops_glob,
        "hlo_bytes_global": bytes_glob,
        "collective_bytes_per_dev": coll_dev,
        "model_flops": mf,
        "useful_ratio": (mf / flops_glob) if flops_glob else float("nan"),
        "chips": chips,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="benchmarks/results/dryrun.json")
    ap.add_argument("--out", default="benchmarks/results/roofline.json")
    ap.add_argument("--md", action="store_true", help="print markdown table")
    args = ap.parse_args()

    with open(args.inp) as f:
        records = json.load(f)
    rows = []
    for rec in sorted(records, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        a = analyze_record(rec)
        if a is None:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": rec["status"],
                         "reason": rec.get("reason", rec.get("error", ""))[:80]})
            continue
        rows.append({"arch": rec["arch"], "shape": rec["shape"],
                     "mesh": rec["mesh"], "status": "ok", **a})
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)

    if args.md:
        hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
               "dominant | useful |")
        print(hdr)
        print("|" + "---|" * 8)
        for r in rows:
            if r["status"] != "ok":
                print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — "
                      f"| {r['status']}: {r.get('reason','')} | — |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                  f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
                  f"| {r['t_collective_s']:.4f} | {r['dominant']} "
                  f"| {r['useful_ratio']:.2f} |")
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
