import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks
# the device count at first initialization) — do not move or reorder.

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each combination this script:
  1. builds the production mesh (16,16) single-pod / (2,16,16) multi-pod,
  2. builds the right step (CycleSL train round / prefill / decode),
  3. ``jit(...).lower(...).compile()`` with ShapeDtypeStruct inputs only,
  4. records memory_analysis / cost_analysis / collective bytes parsed
     from the optimized HLO into benchmarks/results/dryrun.json.

Failures here are bugs in the sharding/distribution config, per the
deliverable contract.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import INPUT_SHAPES
from repro.configs.registry import get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.utils import hlo, hlo_cost

# long_500k applicability (DESIGN.md §5): whisper is skipped outright;
# full-attention archs run their documented sliding-window serving
# variant (long_context=True), SSM/hybrid run natively.
LONG_SKIP = {"whisper-base": "enc-dec, 448-pos decoder horizon; full attn"}


def _mem_stats(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:  # noqa: BLE001
        out["error"] = repr(e)
    return out


def _cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # noqa: BLE001
        return {"error": repr(e)}


def run_one(arch: str, shape_name: str, multi_pod: bool,
            keep_hlo: bool = False, server_batch: int | None = None) -> dict:
    from repro.core.cyclesl import CycleConfig
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "status": "ok"}
    if server_batch:
        rec["server_batch"] = server_batch
    if shape_name == "long_500k" and arch in LONG_SKIP:
        rec["status"] = "skipped"
        rec["reason"] = LONG_SKIP[arch]
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        bundle = build_step(cfg, mesh, shape,
                            cycle=CycleConfig(server_batch=server_batch))
        with mesh:
            jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings,
                             donate_argnums=bundle.donate)
            lowered = jitted.lower(*bundle.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        rec["step"] = bundle.name
        rec["lower_s"] = round(t_lower, 2)
        rec["compile_s"] = round(t_compile, 2)
        rec["memory"] = _mem_stats(compiled)
        rec["cost"] = _cost_stats(compiled)          # raw XLA (body-once)
        text = compiled.as_text()
        rec["collectives"] = hlo.collective_stats(text).summary()
        # loop-aware per-device cost model (trip-count-corrected)
        mc = hlo_cost.module_cost(text)
        rec["loop_aware"] = mc.summary()
        rec["n_devices"] = mesh.devices.size
        if keep_hlo:
            rec["hlo_len"] = len(text)
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*INPUT_SHAPES, None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    ap.add_argument("--server-batch", type=int, default=None,
                    help="CycleSL server inner-loop batch (perf knob)")
    ap.add_argument("--resume", action="store_true",
                    help="skip combos already ok in --out")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results
                if r["status"] in ("ok", "skipped")}

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for arch in archs:
            for shape in shapes:
                if (arch, shape, mesh_name) in done:
                    continue
                rec = run_one(arch, shape, mp, server_batch=args.server_batch)
                results = [r for r in results
                           if not (r["arch"] == arch and r["shape"] == shape
                                   and r["mesh"] == mesh_name)]
                results.append(rec)
                flops = rec.get("cost", {}).get("flops", float("nan"))
                print(f"[{rec['status']:7s}] {mesh_name} {arch:22s} "
                      f"{shape:12s} {rec.get('total_s', 0):7.1f}s "
                      f"flops={flops:.3e} "
                      f"coll={rec.get('collectives', {}).get('total_bytes', 0):.3e}",
                      flush=True)
                if rec["status"] == "error":
                    print(rec["error"], flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
