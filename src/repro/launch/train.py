"""Federated split-learning training driver (runs for real on CPU).

Trains any StageModel task with any SL algorithm from the zoo on the
synthetic federated datasets — the end-to-end example driver
(deliverable (b)): a ~100M-param run is just ``--arch`` + width knobs
away, the default is CPU-sized so it finishes in minutes.

Usage:
  PYTHONPATH=src python -m repro.launch.train \
      --algo cyclesfl --task image --rounds 200 --clients 100
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core.algorithms import ALGORITHMS, make_algorithm
from repro.core.cyclesl import CycleConfig
from repro.core.drift import GradStabilityTracker
from repro.core.split import make_stage_task
from repro.data.federated import FederatedDataset, sample_cohort
from repro.data.synthetic import (SyntheticCharLMTask, SyntheticImageTask,
                                  SyntheticRegressionTask)
from repro.models.cnn import femnist_cnn, mlp, resnet9
from repro.models.lstm import shakespeare_lstm
from repro.optim import adam


def build_task(name: str, n_clients: int, alpha: float, seed: int,
               width: int, cut: int):
    if name == "image":
        gen = SyntheticImageTask(n_clients=n_clients, alpha=alpha, seed=seed)
        x, y, _, idx = gen.build()
        model = femnist_cnn(n_classes=gen.n_classes, width=width)
        task = make_stage_task(model, cut=cut, kind="xent")
        x = x.reshape(len(x), gen.img, gen.img, gen.channels)
        # femnist cnn expects 28x28x1; adapt by padding channels->1 proj
        x = x.mean(axis=-1, keepdims=True)
        x = np.pad(x, ((0, 0), (6, 6), (6, 6), (0, 0)))
        return task, FederatedDataset.from_arrays(x, y, idx, seed=seed), "accuracy"
    if name == "cifar":
        gen = SyntheticImageTask(n_clients=n_clients, alpha=alpha, seed=seed,
                                 img=32, n_classes=20, samples_per_client=96)
        x, y, _, idx = gen.build()
        model = resnet9(n_classes=20, width=width)
        task = make_stage_task(model, cut=cut, kind="xent")
        return task, FederatedDataset.from_arrays(x, y, idx, seed=seed), "accuracy"
    if name == "charlm":
        gen = SyntheticCharLMTask(n_clients=n_clients, seed=seed)
        x, y, _, idx = gen.build()
        model = shakespeare_lstm(vocab=gen.vocab)
        task = make_stage_task(model, cut=2, kind="xent")
        return task, FederatedDataset.from_arrays(x, y, idx, seed=seed), "accuracy"
    if name == "gaze":
        gen = SyntheticRegressionTask(n_clients=n_clients, seed=seed)
        x, y, _, idx = gen.build()
        model = mlp(gen.d_in, [128, 64], gen.d_out)
        task = make_stage_task(model, cut=1, kind="mse")
        return task, FederatedDataset.from_arrays(x, y, idx, seed=seed), "angular_deg"
    raise KeyError(name)


def evaluate(task, state, fed, batch: int = 256, max_batches: int = 8,
             max_clients: int = 40):
    """Test metrics matching the paper's protocol (§4.1).

    SFL-family (global client model): pooled sample-wise test set.
    PSL-family (per-client models, never aggregated): per-client
    evaluation — each client's test samples are scored with THAT
    client's model, sample-weighted (a mean of unsynced client models
    is not a model anyone owns).
    """
    if state.client_global is not None:
        cp = state.client_global.params
        xs, ys = fed.test_arrays()
        n = min(len(xs), batch * max_batches)
        losses, mets, ws = [], [], []
        for i in range(0, n, batch):
            out = task.predict(cp, state.server.params,
                               jnp.asarray(xs[i:i + batch]))
            losses.append(float(task.loss(out, jnp.asarray(ys[i:i + batch]))))
            mets.append({k: float(v) for k, v in
                         task.metrics(out, jnp.asarray(ys[i:i + batch])).items()})
            ws.append(len(xs[i:i + batch]))
        agg = {k: float(np.average([m[k] for m in mets], weights=ws))
               for k in mets[0]}
        return float(np.average(losses, weights=ws)), agg

    # per-client evaluation (vmapped: one trace, truncated to the common
    # test size so client stacks are rectangular)
    idxs = [i for i, c in enumerate(fed.clients) if len(c.x_test)][:max_clients]
    t = min(len(fed.clients[i].x_test) for i in idxs)
    xs = jnp.asarray(np.stack([fed.clients[i].x_test[:t] for i in idxs]))
    ys = jnp.asarray(np.stack([fed.clients[i].y_test[:t] for i in idxs]))
    cps = jax.tree.map(lambda x: x[np.asarray(idxs)], state.clients.params)
    sp = state.server.params

    def one(cp, x, y):
        out = task.predict(cp, sp, x)
        return task.loss(out, y), task.metrics(out, y)

    losses, mets = jax.vmap(one)(cps, xs, ys)
    agg = {k: float(jnp.mean(v)) for k, v in mets.items()}
    return float(jnp.mean(losses)), agg


def run(algo_name: str, task_name: str = "image", rounds: int = 100,
        n_clients: int = 100, attendance: float = 0.05, batch: int = 16,
        lr_server: float = 1e-3, lr_client: float = 1e-3, alpha: float = 0.5,
        server_epochs: int = 1, seed: int = 0, width: int = 16, cut: int = 2,
        eval_every: int = 20, ckpt_dir: str | None = None, log=print):
    task, fed, metric_key = build_task(task_name, n_clients, alpha, seed,
                                       width, cut)
    algo = make_algorithm(algo_name, task, adam(lr_server), adam(lr_client),
                          CycleConfig(server_epochs=server_epochs))
    state = algo.init(jax.random.PRNGKey(seed), fed.n_clients)
    rng = np.random.default_rng(seed + 1)
    tracker = GradStabilityTracker()
    history = []
    t0 = time.time()
    for rnd in range(rounds):
        cohort = sample_cohort(fed.n_clients, attendance, rng, min_cohort=2)
        xs = np.stack([fed.clients[c].sample_batch(rng, batch)[0] for c in cohort])
        ys = np.stack([fed.clients[c].sample_batch(rng, batch)[1] for c in cohort])
        state, metrics = algo.round(state, jnp.asarray(cohort),
                                    jnp.asarray(xs), jnp.asarray(ys),
                                    jax.random.PRNGKey(seed * 100_000 + rnd))
        tracker.update(metrics)
        if (rnd + 1) % eval_every == 0 or rnd == rounds - 1:
            loss, mets = evaluate(task, state, fed)
            history.append({"round": rnd + 1, "test_loss": loss, **mets,
                            "train_loss": float(metrics["server_loss"]),
                            "elapsed_s": round(time.time() - t0, 1)})
            log(f"[{algo_name}] round {rnd+1:4d} test_loss={loss:.4f} "
                f"{metric_key}={mets[metric_key]:.4f}")
            if ckpt_dir:
                save_checkpoint(ckpt_dir, rnd + 1, state,
                                metadata={"algo": algo_name})
    return {"algo": algo_name, "task": task_name, "history": history,
            "grad_stability": tracker.summary()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="cyclesfl", choices=sorted(ALGORITHMS))
    ap.add_argument("--task", default="image",
                    choices=["image", "cifar", "charlm", "gaze"])
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--attendance", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--server-epochs", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--cut", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(args.algo, args.task, args.rounds, args.clients,
              args.attendance, args.batch, alpha=args.alpha,
              server_epochs=args.server_epochs, seed=args.seed,
              width=args.width, cut=args.cut, ckpt_dir=args.ckpt_dir)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
    print(json.dumps(res["history"][-1] if res["history"] else {}, indent=1))


if __name__ == "__main__":
    main()
