"""Federated split-learning training CLI (runs for real on CPU).

Thin flag-parsing front-end over the one driver loop,
``repro.api.Engine``: build an :class:`~repro.api.ExperimentConfig`
from flags (or kwargs via :func:`run`) and call ``Engine.run()``.
A ~100M-param run is just ``--arch`` + width knobs away; the default is
CPU-sized so it finishes in minutes.

Usage:
  PYTHONPATH=src python -m repro.launch.train \
      --algo cyclesfl --task image --rounds 200 --clients 100
"""
from __future__ import annotations

import argparse
import json
import os

from repro.api import Engine, ExperimentConfig
# re-exported for backwards compatibility (tests and notebooks import
# these from here; they now live in repro.api)
from repro.api.engine import evaluate            # noqa: F401
from repro.api.tasks import build_task           # noqa: F401
from repro.core.cyclesl import CycleConfig


def run(algo_name: str, task_name: str = "image", rounds: int = 100,
        n_clients: int = 100, attendance: float = 0.05, batch: int = 16,
        lr_server: float = 1e-3, lr_client: float = 1e-3, alpha: float = 0.5,
        server_epochs: int = 1, seed: int = 0, width: int = 16, cut: int = 2,
        eval_every: int = 20, ckpt_dir: str | None = None, log=print):
    """Kwargs-style wrapper kept for the examples/tests; new code should
    construct an ExperimentConfig and an Engine directly."""
    cfg = ExperimentConfig(
        algo=algo_name, task=task_name, rounds=rounds, n_clients=n_clients,
        attendance=attendance, batch=batch, lr_server=lr_server,
        lr_client=lr_client, alpha=alpha, seed=seed, width=width, cut=cut,
        eval_every=eval_every, ckpt_dir=ckpt_dir,
        cycle=CycleConfig(server_epochs=server_epochs))
    return Engine(cfg, log=log).run()


def main():
    ap = argparse.ArgumentParser()
    ExperimentConfig.add_arguments(ap)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cfg = ExperimentConfig.from_flags(args)
    res = Engine(cfg).run()
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
    print(json.dumps(res["history"][-1] if res["history"] else {}, indent=1))


if __name__ == "__main__":
    main()
