"""Production mesh builders (TPU v5e target).

Single pod : (data=16, model=16)            = 256 chips
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

Functions, not module constants, so importing this module never touches
jax device state (the dry-run forces 512 host devices *before* any jax
initialization — see dryrun.py).
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run via "
            "launch/dryrun.py which forces XLA_FLAGS host device count")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_local_mesh():
    """Degenerate 1x1 mesh for CPU tests/benchmarks."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def make_engine_mesh(shape, axes):
    """Mesh from the serializable ``ExperimentConfig.mesh_shape`` /
    ``mesh_axes`` knobs, laid over the first prod(shape) devices.

    Unlike the fixed production meshes above, this accepts any
    shape/axes pair (Engine experiments sweep device counts via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(f"mesh_shape {shape} and mesh_axes {axes} must "
                         "have equal length")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            "jax initializes (see benchmarks/bench_round.py --devices)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def cohort_size(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
