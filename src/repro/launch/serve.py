"""Split-serving driver: batched decode with the composed model.

Runs for real on CPU with a smoke-sized arch (``--smoke``, default) and
demonstrates the full serve path the decode dry-run shapes lower:
prefill a prompt batch, then step the KV/SSM cache token by token.

``--continuous`` switches decoder-only archs to the production path:
the fixed-slot continuous-batching runtime in :mod:`repro.serve`
(compile-once slot table, deadlines, retry/backoff) driven by the
closed-loop load generator.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --steps 16
  PYTHONPATH=src python -m repro.launch.serve --continuous --concurrency 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs, smoke_config
from repro.models.encdec import EncDec
from repro.models.transformer import Transformer


def serve_decoder_only(cfg, batch: int, prompt_len: int, steps: int,
                       seed: int = 0):
    if batch < 1:
        raise ValueError(f"batch={batch} must be >= 1")
    if prompt_len < 0 or steps < 0:
        raise ValueError(f"prompt_len={prompt_len} and steps={steps} must "
                         "be >= 0")
    key = jax.random.PRNGKey(seed)
    params = Transformer.init(key, cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                0, cfg.vocab)
    # capacity >= 1 keeps the zero-work edge (prompt_len=0, steps=0) a
    # well-defined no-op instead of a degenerate 0-length ring buffer
    state = Transformer.init_decode_state(cfg, batch,
                                          max(prompt_len + steps, 1))

    decode = jax.jit(lambda p, t, s: Transformer.decode_step(p, cfg, t, s))
    # prefill by stepping the prompt through the SAME jitted step the
    # decode loop uses (cache-exact, CPU-friendly): one trace total, so
    # prefill_s measures the model, not per-token retrace overhead
    logits = None
    tok = jnp.zeros((batch, 1), jnp.int32)
    t0 = time.time()
    for i in range(prompt_len):
        logits, state = decode(params, prompt[:, i:i+1], state)
    if prompt_len:
        jax.block_until_ready(logits)
        # greedy continuation: generation starts from the token the
        # prefilled prompt predicts, not a replay of the prompt's start
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0
    out_tokens = []
    t0 = time.time()
    for _ in range(steps):
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = (jnp.concatenate(out_tokens, axis=1) if out_tokens
            else jnp.zeros((batch, 0), jnp.int32))
    if logits is not None:
        assert bool(jnp.isfinite(logits).all()), \
            "non-finite logits in serve loop"
    return {"tokens": toks, "prefill_s": t_prefill,
            "decode_s_per_token": dt / steps if steps else 0.0,
            "batch": batch}


def serve_whisper(cfg, batch: int, steps: int, seed: int = 0):
    if batch < 1:
        raise ValueError(f"batch={batch} must be >= 1")
    if steps < 0:
        raise ValueError(f"steps={steps} must be >= 0")
    key = jax.random.PRNGKey(seed)
    params = EncDec.init(key, cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (batch, 60, cfg.enc_d_model), cfg.jnp_dtype) * 0.1
    state = EncDec.init_decode_state(params, cfg, frames, seq_len=steps + 1)
    decode = jax.jit(lambda p, t, s: EncDec.decode_step(p, cfg, t, s))
    logits = None
    tok = jnp.zeros((batch, 1), jnp.int32)
    outs = []
    t0 = time.time()
    for _ in range(steps):
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs.append(tok)
    dt = time.time() - t0
    if logits is not None:
        assert bool(jnp.isfinite(logits).all())
    return {"tokens": (jnp.concatenate(outs, axis=1) if outs
                       else jnp.zeros((batch, 0), jnp.int32)),
            "decode_s_per_token": dt / steps if steps else 0.0,
            "batch": batch}


def serve_continuous(cfg, serve_cfg, concurrency: int, n_requests: int,
                     seed: int = 0):
    """Drive the continuous-batching runtime with a closed loop."""
    from repro.serve import ServeRuntime, make_prompts, run_closed_loop
    rt = ServeRuntime(cfg, serve_cfg, seed=seed)
    prompts = make_prompts(n_requests, serve_cfg.max_prompt_len, cfg.vocab,
                           seed=seed + 1)
    row = run_closed_loop(rt, prompts, concurrency=concurrency)
    row["traces"] = dict(rt.traces)
    row["max_slot_reuse"] = rt.stats()["max_slot_reuse"]
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--full", action="store_true",
                    help="use the full config (needs real accelerators)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="serve via the fixed-slot continuous-batching "
                         "runtime (decoder-only archs)")
    ap.add_argument("--concurrency", type=int, default=4,
                    help="closed-loop client count (--continuous)")
    ap.add_argument("--requests", type=int, default=16,
                    help="total requests to serve (--continuous)")
    from repro.serve import ServeConfig
    ServeConfig.add_arguments(ap)
    args = ap.parse_args()
    cfg = get_config(args.arch) if args.full else smoke_config(args.arch)
    if args.continuous:
        if cfg.family == "audio":
            ap.error("--continuous serves decoder-only archs")
        row = serve_continuous(cfg, ServeConfig.from_flags(args),
                               args.concurrency, args.requests)
        print(f"arch={cfg.name} continuous serve:")
        for k, v in row.items():
            print(f"  {k}: {v}")
        return
    if cfg.family == "audio":
        res = serve_whisper(cfg, args.batch, args.steps)
    else:
        res = serve_decoder_only(cfg, args.batch, args.prompt_len, args.steps)
    toks = res.pop("tokens")
    print(f"arch={cfg.name} generated {toks.shape[1]} tokens x{toks.shape[0]} seqs")
    print({k: (round(v, 5) if isinstance(v, float) else v)
           for k, v in res.items()})
    print("sample:", toks[0][:12].tolist())


if __name__ == "__main__":
    main()
