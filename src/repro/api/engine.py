"""The ONE driver loop every entrypoint shares.

``Engine`` owns the cohort-sampling / round / eval / checkpoint cycle
that ``launch/train.py``, ``benchmarks/*``, and the examples used to
hand-roll: build (or accept) a task + federated dataset, compile the
algorithm's RoundProgram into a jitted round (TrainState buffers donated
off-CPU), then drive it for ``cfg.rounds`` rounds with the paper's
protocol (partial attendance, sample-wise eval split, fixed per-round
key stream).

Rounds are compile-once: every cohort is padded to the static capacity
``C_max = ceil(attendance * N)`` with an attendance mask threaded
through the round (see :mod:`repro.api.phases`), so the jitted round
traces exactly once per experiment no matter how live attendance varies
round to round — wall-clock measures the algorithm, not XLA retraces.

Mesh-native execution: with ``cfg.mesh_shape`` set the Engine builds
the device mesh ONCE, places the TrainState with ``NamedSharding`` (the
client stack's leading cohort dim over the batch axes, server weights
FSDP/TP per :mod:`repro.sharding.specs` path rules), commits every
round input to the batch axes, and pins the round's output shardings —
one trace per (algo, config, mesh), and the 1-device mesh is bit-for-
bit the unsharded path (constraints pin layout, never values).
``cfg.resume`` restores the latest checkpoint under ``ckpt_dir`` and
continues at the saved round with cadence and sampling stream aligned.

Pipelined rounds: ``cfg.pipeline_depth=1`` runs a software pipeline
over two in-flight cohorts — cohort k+1's ExtractFeatures dispatch
(batch axes) against cohort k's ServerUpdate..Commit tail (model axes),
with prefetched cohort sampling and a double-buffered
:class:`~repro.api.phases.PipelineStage`.  ``pipeline_staleness='sync'``
is bit-for-bit the sequential loop; ``'async'`` overlaps with exactly
one round of client/θ_S^t staleness (see ARCHITECTURE.md "Pipelined
execution" and tests/test_pipeline.py).

Pluggable callbacks observe the loop without forking it::

    eng = Engine(ExperimentConfig(algo="cyclesfl", rounds=100))
    result = eng.run()           # {"history": [...], "grad_stability": ...}

Callbacks are any objects exposing ``on_round(engine, rnd, state,
metrics)`` and/or ``on_eval(engine, rnd, loss, mets)``.
"""
from __future__ import annotations

import math
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import ExperimentConfig
from repro.api.phases import (PipelinedAlgorithm, SLAlgorithm, TrainState,
                              build_algorithm, build_pipelined_algorithm,
                              init_train_state)
from repro.api.registry import get_program
from repro.api.tasks import build_task
from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.core.drift import GradStabilityTracker
from repro.core.split import SplitTask
from repro.data.federated import FederatedDataset, sample_cohort
from repro.launch.mesh import make_engine_mesh
from repro.optim import adam
from repro.scenario.profiles import build_profile_stream
from repro.sharding.specs import batch_spec, train_state_shardings


def evaluate(task, state, fed, batch: int = 256, max_batches: int = 8,
             max_clients: int = 40):
    """Test metrics matching the paper's protocol (§4.1).

    SFL-family (global client model): pooled sample-wise test set.
    PSL-family (per-client models, never aggregated): per-client
    evaluation — each client's test samples are scored with THAT
    client's model, sample-weighted (a mean of unsynced client models
    is not a model anyone owns).
    """
    if state.client_global is not None:
        # pooled sample-wise test set: stack the full batches into ONE
        # vmapped device call, score the remainder in a second call, and
        # sync device->host once at the end (instead of a float() sync
        # per test batch, which serializes host and device)
        cp, sp = state.client_global.params, state.server.params
        xs, ys = fed.test_arrays()
        n = min(len(xs), batch * max_batches)
        nfull, rem = divmod(n, batch)

        def one(x, y):
            out = task.predict(cp, sp, x)
            return task.loss(out, y), task.metrics(out, y)

        losses, mets, ws = [], [], []
        if nfull:
            xb = jnp.asarray(xs[:nfull * batch]).reshape(
                (nfull, batch) + xs.shape[1:])
            yb = jnp.asarray(ys[:nfull * batch]).reshape(
                (nfull, batch) + ys.shape[1:])
            lb, mb = jax.vmap(one)(xb, yb)
            losses.append(lb)
            mets.append(mb)
            ws += [batch] * nfull
        if rem:
            lr_, mr = one(jnp.asarray(xs[nfull * batch:n]),
                          jnp.asarray(ys[nfull * batch:n]))
            losses.append(jnp.reshape(lr_, (1,)))
            mets.append(jax.tree.map(lambda v: jnp.reshape(v, (1,)), mr))
            ws.append(rem)
        losses, mets = jax.device_get((jnp.concatenate(losses),
                                       {k: jnp.concatenate([m[k] for m in mets])
                                        for k in mets[0]}))
        agg = {k: float(np.average(v, weights=ws)) for k, v in mets.items()}
        return float(np.average(losses, weights=ws)), agg

    # per-client evaluation (vmapped: one trace, truncated to the common
    # test size so client stacks are rectangular)
    idxs = [i for i, c in enumerate(fed.clients) if len(c.x_test)][:max_clients]
    t = min(len(fed.clients[i].x_test) for i in idxs)
    xs = jnp.asarray(np.stack([fed.clients[i].x_test[:t] for i in idxs]))
    ys = jnp.asarray(np.stack([fed.clients[i].y_test[:t] for i in idxs]))
    cps = jax.tree.map(lambda x: x[np.asarray(idxs)], state.clients.params)
    sp = state.server.params

    def one(cp, x, y):
        out = task.predict(cp, sp, x)
        return task.loss(out, y), task.metrics(out, y)

    losses, mets = jax.vmap(one)(cps, xs, ys)
    agg = {k: float(jnp.mean(v)) for k, v in mets.items()}
    return float(jnp.mean(losses)), agg


class Engine:
    """Compile once, drive the whole experiment."""

    def __init__(self, cfg: ExperimentConfig, *,
                 task: Optional[SplitTask] = None,
                 fed: Optional[FederatedDataset] = None,
                 metric_key: Optional[str] = None,
                 callbacks: Sequence = (),
                 donate: Optional[bool] = None,
                 log=print):
        cfg.validate()
        if (task is None) != (fed is None):
            raise ValueError("pass BOTH task and fed (they come from one "
                             "generator) or neither")
        if task is None:
            task, fed, mk = build_task(cfg.task, cfg.n_clients, cfg.alpha,
                                       cfg.seed, cfg.width, cfg.cut)
            metric_key = metric_key or mk
        self.cfg = cfg
        self.task = task
        self.fed = fed
        self.metric_key = metric_key or "accuracy"
        self.callbacks = tuple(callbacks)
        self.log = log
        if donate is None:
            # buffer donation is a no-op XLA warning on CPU; enable elsewhere
            donate = jax.default_backend() != "cpu"
        program = get_program(cfg.algo)
        opt_s, opt_c = adam(cfg.lr_server), adam(cfg.lr_client)
        # ---- mesh-native execution: build the mesh ONCE, derive the
        # TrainState placement from the path-regex rules (server weights
        # FSDP/TP, client stack's leading cohort dim over the batch
        # axes), and pin it as the jitted round's out_shardings so the
        # state sharding is stable round-over-round (compile-once per
        # (algo, config, mesh)).
        self.mesh = (make_engine_mesh(cfg.mesh_shape, cfg.mesh_axes)
                     if cfg.mesh_shape is not None else None)
        self.state_shardings = None
        if self.mesh is not None:
            a_state = jax.eval_shape(lambda: init_train_state(
                jax.random.PRNGKey(0), fed.n_clients, task, opt_s, opt_c,
                program.uses_global_client))
            self.state_shardings = train_state_shardings(
                a_state, self.mesh, shard_cohort=cfg.shard_cohort)
        # ---- client-population scenario: the profile stream feeding
        # per-round attendance weights + drop/lag events.  None for the
        # null scenario (kind='none') — every scenario branch below is
        # then skipped and the run is bit-for-bit scenario-free.
        self.scenario = build_profile_stream(cfg.scenario, fed.n_clients,
                                             cfg.seed)
        self._sample_clock = 0            # rounds drawn so far (scenario
                                          # streams fold this in, resume
                                          # fast-forwards it)
        self._telemetry: list[dict] = []  # one row per sampled round
        # the θ staleness the schedule can realize: async pipelining
        # carries a snapshot exactly one round old; everything else
        # delivers fresh params (a straggler's *drawn* lag can exceed
        # this — its realized lag is capped by the schedule)
        self._sched_lag = int(cfg.pipeline_depth > 0
                              and cfg.pipeline_staleness == "async")
        churns = self.scenario is not None and self.scenario.churns
        if (cfg.pad_cohorts and (cfg.variable_attendance or churns)
                and any(getattr(p, "mode", None) == "cycle"
                        for p in program.phases)):
            # the masked inner loop's server batch is static; if it can
            # exceed the smallest possible live pool (min_cohort clients),
            # a low-attendance or churn-thinned round would fill ZERO
            # valid steps and the server would silently not train that
            # round — reject upfront
            sb = cfg.cycle.server_batch or cfg.batch
            if sb > cfg.batch * cfg.min_cohort:
                raise ValueError(
                    f"cycle.server_batch={sb} can exceed the smallest "
                    f"possible live feature pool (min_cohort={cfg.min_cohort}"
                    f" x batch={cfg.batch} = {cfg.min_cohort * cfg.batch} "
                    "rows) under variable attendance or scenario churn, "
                    "which would leave the server inner loop with zero "
                    "valid steps in sparse rounds; lower cycle.server_batch "
                    "or raise min_cohort")
        self.algo: SLAlgorithm = build_algorithm(
            program, task, opt_s, opt_c, cfg.cycle,
            donate=donate, mesh=self.mesh,
            state_shardings=self.state_shardings,
            shard_data=cfg.shard_cohort)
        # ---- pipelined rounds: compile the (extract, tail) dispatch
        # pair so cohort k+1's feature extraction can be in flight while
        # cohort k's server phase runs.  None for the fused sequential
        # programs (nothing to overlap) — the run loop falls back to the
        # monolithic round.  The TrainState is only donated into the
        # tail in sync mode: async mode keeps the pre-tail state alive
        # inside the next cohort's extract dispatch.
        self.pipeline: Optional[PipelinedAlgorithm] = None
        self.pipeline_stats: dict = {}
        if cfg.pipeline_depth > 0:
            self.pipeline = build_pipelined_algorithm(
                program, task, opt_s, opt_c, cfg.cycle,
                donate=donate,
                donate_state=(cfg.pipeline_staleness == "sync"),
                mesh=self.mesh, state_shardings=self.state_shardings,
                shard_data=cfg.shard_cohort)

    # ------------------------------------------------------------ state
    def init_state(self) -> TrainState:
        state = self.algo.init(jax.random.PRNGKey(self.cfg.seed),
                               self.fed.n_clients)
        if self.state_shardings is not None:
            state = jax.device_put(state, self.state_shardings)
        return state

    def _place(self, arr):
        """Commit a [C, ...] round input to the mesh batch axes (leading
        cohort dim; no-op off-mesh or with cohort sharding disabled)."""
        x = jnp.asarray(arr)
        if self.mesh is None or not self.cfg.shard_cohort:
            return x
        from jax.sharding import NamedSharding
        spec = batch_spec(self.mesh, x.shape[0], x.ndim - 1)
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def round_key(self, rnd: int):
        return jax.random.PRNGKey(self.cfg.seed * self.cfg.round_key_salt
                                  + rnd)

    @property
    def cohort_capacity(self) -> int:
        """C_max: the static cohort shape every round is padded to.

        Deterministic attendance always draws exactly
        ``round(attendance * N)`` clients, so the capacity matches the
        sampler and no slot is ever padded; only variable attendance
        needs the ceil upper bound (Binomial draws above the mean are
        clipped to it).
        """
        cfg = self.cfg
        n = self.fed.n_clients
        if cfg.variable_attendance:
            # tolerant ceil: 0.3 * 20 is 6.000000000000001 in binary
            cap = math.ceil(cfg.attendance * n - 1e-9)
        else:
            cap = round(cfg.attendance * n)
        return min(max(cfg.min_cohort, cap), n)

    def _sample_cohort_ids(self, rng: np.random.Generator):
        """Draw one round's live cohort, advancing the sample clock.

        Called exactly once per round by both :meth:`sample_round` and
        :meth:`_replay_sampling`, so the clock (which time-varying
        scenario streams fold into their attendance weights) stays
        aligned across resume replays.  The null scenario contributes
        ``weights=None`` — ``rng.choice`` then takes the exact same
        draw path as the scenario-free Engine (bit-for-bit cohorts).
        """
        cfg = self.cfg
        rnd = self._sample_clock
        self._sample_clock = rnd + 1
        weights = (self.scenario.weights(rnd)
                   if self.scenario is not None else None)
        return sample_cohort(self.fed.n_clients, cfg.attendance, rng,
                             min_cohort=cfg.min_cohort,
                             variable=cfg.variable_attendance,
                             max_cohort=(self.cohort_capacity
                                         if cfg.pad_cohorts else None),
                             weights=weights)

    def _replay_sampling(self, rng: np.random.Generator, rounds: int):
        """Consume exactly the RNG draws ``rounds`` rounds of
        :meth:`sample_round` would make — cohort ids plus each member's
        batch indices — without materializing, padding, or placing any
        array.  Resume fast-forwards through this so round ``n`` of a
        resumed run draws the same cohort an uninterrupted run would."""
        for _ in range(rounds):
            for c in self._sample_cohort_ids(rng):
                self.fed.clients[c].sample_indices(rng, self.cfg.batch)

    def sample_round(self, rng: np.random.Generator):
        """Cohort ids, aligned per-client (x, y) batches, and the
        attendance mask for one round.

        With ``cfg.pad_cohorts`` (the default) the cohort is padded to
        the static :attr:`cohort_capacity`: padded slots carry the
        out-of-range sentinel id N (dropped by the commit scatter),
        zeroed batches, and a 0 in the mask — so the jitted round sees
        ONE shape for the whole experiment regardless of live
        attendance.  ``mask`` is ``None`` when padding is disabled.

        Scenario churn rides the same mask: a mid-round dropout (hazard
        draw, or a straggler whose drawn lag exceeds its staleness
        bound — a deadline miss) zeroes its LIVE slot, so its features
        never enter a valid server minibatch and its commit is skipped —
        exactly the padded-slot machinery, no new trace.  The client's
        batch is still drawn first, keeping the rng stream identical to
        a no-churn round.
        """
        cfg = self.cfg
        cap = self.cohort_capacity if cfg.pad_cohorts else None
        cohort = self._sample_cohort_ids(rng)
        rnd = self._sample_clock - 1       # the round that draw was for
        live = len(cohort)
        pairs = [self.fed.clients[c].sample_batch(rng, cfg.batch)
                 for c in cohort]
        xs = np.stack([p[0] for p in pairs])
        ys = np.stack([p[1] for p in pairs])
        row = {"round": rnd, "cohort": live, "live": live, "dropped": 0,
               "drop_hazard": 0, "drop_deadline": 0, "lag_drawn_max": 0,
               "realized_lag": 0}
        if cap is None:
            self._telemetry.append(row)
            return (self._place(cohort), self._place(xs), self._place(ys),
                    None)
        pad = cap - live
        mask = np.ones(cap, np.float32)
        if pad:
            cohort = np.concatenate(
                [cohort, np.full(pad, self.fed.n_clients, cohort.dtype)])
            xs = np.concatenate([xs, np.zeros((pad,) + xs.shape[1:],
                                              xs.dtype)])
            ys = np.concatenate([ys, np.zeros((pad,) + ys.shape[1:],
                                              ys.dtype)])
            mask[-pad:] = 0.0
        if self.scenario is not None and self.scenario.churns:
            ev = self.scenario.events(rnd, cohort[:live],
                                      min_live=cfg.min_cohort)
            mask[:live] *= ev.keep
            kept = int(ev.keep.sum())
            row.update(live=kept, dropped=live - kept,
                       drop_hazard=ev.hazard_drops,
                       drop_deadline=ev.deadline_drops,
                       lag_drawn_max=int(ev.lag.max()) if live else 0)
        self._telemetry.append(row)
        return (self._place(cohort), self._place(xs), self._place(ys),
                self._place(mask))

    def _emit(self, hook: str, *args):
        for cb in self.callbacks:
            fn = getattr(cb, hook, None)
            if fn is not None:
                fn(self, *args)

    # ---------------------------------------------------------- resume
    def restore(self, rng: np.random.Generator
                ) -> tuple[Optional[TrainState], int]:
        """Load the latest checkpoint under ``cfg.ckpt_dir`` and return
        ``(state, start_round)``; ``(None, 0)`` when nothing to resume.

        The checkpoint step is the 1-based round it was saved after, so
        the run continues at exactly that round index and the eval/ckpt
        cadence (``(rnd + 1) % eval_every``) stays aligned.  The cohort-
        sampling stream is replayed through the skipped rounds so round
        ``start_round`` draws the same cohort an uninterrupted run would
        have drawn.
        """
        cfg = self.cfg
        step = latest_step(cfg.ckpt_dir) if cfg.ckpt_dir else None
        if step is None:
            return None, 0
        # structure/dtype template only — no init compute or placement
        template = jax.eval_shape(
            lambda: self.algo.init(jax.random.PRNGKey(cfg.seed),
                                   self.fed.n_clients))
        state, _ = load_checkpoint(cfg.ckpt_dir, template, step=step)
        if self.state_shardings is not None:
            state = jax.device_put(state, self.state_shardings)
        self._replay_sampling(rng, step)
        self.log(f"[{self.algo.name}] resumed from {cfg.ckpt_dir} at "
                 f"round {step}")
        return state, step

    # --------------------------------------------------------- pipeline
    def _extract(self, state, inputs):
        """Dispatch the ExtractFeatures head for one cohort."""
        cohort, xs, ys, mask = inputs
        if mask is None:
            return self.pipeline.extract(state, cohort, xs, ys)
        return self.pipeline.extract(state, cohort, xs, ys, mask)

    def _tail(self, state, inputs, stage, key):
        """Dispatch the ServerUpdate..Commit tail consuming ``stage``."""
        cohort, xs, ys, mask = inputs
        if mask is None:
            return self.pipeline.tail(state, cohort, xs, ys, key, stage)
        return self.pipeline.tail(state, cohort, xs, ys, key, stage, mask)

    # -------------------------------------------------------------- run
    def run(self, state: Optional[TrainState] = None) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 1)
        start_round = 0
        if state is None and cfg.resume:
            state, start_round = self.restore(rng)
        if state is None:
            state = self.init_state()
        elif self.state_shardings is not None:
            # caller-provided (or restored) states must sit on the mesh
            # placement the jitted round's out_shardings pin, or round 1
            # would see a different input sharding than round 0 and
            # retrace — no-op when already placed
            state = jax.device_put(state, self.state_shardings)
        tracker = GradStabilityTracker()
        history = []
        round_time, timed_rounds = 0.0, 0
        t0 = time.time()
        # ---- pipeline prime: sample cohort ``start_round`` and put its
        # extraction in flight (async dispatch — does not block the host).
        # On resume the restored state re-primes the pipeline, so the
        # first post-resume extract is fresh (lag 0), exactly like the
        # uninterrupted run's warm-up round.
        pipelined = self.pipeline is not None
        t_tel = len(self._telemetry)     # rows this run will append start here
        stage, stage_src, inputs, max_lag = None, start_round, None, 0
        if pipelined and start_round < cfg.rounds:
            inputs = self.sample_round(rng)
            stage = self._extract(state, inputs)
        for rnd in range(start_round, cfg.rounds):
            if pipelined:
                # prefetch cohort k+1's sampling while round k's compute
                # is (or is about to be) on the devices
                nxt_inputs = (self.sample_round(rng)
                              if rnd + 1 < cfg.rounds else None)
                t_round = time.time()
                nxt = None
                if nxt_inputs is not None \
                        and cfg.pipeline_staleness == "async":
                    # overlap: extract(k+1) from the PRE-tail state — it
                    # shares no dependency with tail(k)'s outputs, so XLA
                    # can run it on the batch axes while the server inner
                    # loop occupies the model axes.  Clients and the
                    # θ_S^t snapshot are stale by exactly one round.
                    nxt = (self._extract(state, nxt_inputs), rnd)
                max_lag = max(max_lag, rnd - stage_src)
                state, metrics = self._tail(state, inputs, stage,
                                            self.round_key(rnd))
                if nxt_inputs is not None and nxt is None:
                    # sync barrier: extract(k+1) reads the post-Commit
                    # state — bit-for-bit the sequential schedule
                    nxt = (self._extract(state, nxt_inputs), rnd + 1)
                if nxt is not None:
                    (stage, stage_src), inputs = nxt, nxt_inputs
            else:
                cohort, xs, ys, mask = self.sample_round(rng)
                t_round = time.time()
                if mask is None:
                    state, metrics = self.algo.round(state, cohort, xs, ys,
                                                     self.round_key(rnd))
                else:
                    state, metrics = self.algo.round(state, cohort, xs, ys,
                                                     self.round_key(rnd),
                                                     mask)
            # telemetry rows are appended at sample time (for pipelined
            # runs that's one round AHEAD of the tail); the θ staleness a
            # round actually saw is only known here, once its tail ran
            ti = t_tel + (rnd - start_round)
            if ti < len(self._telemetry):
                self._telemetry[ti]["realized_lag"] = (
                    rnd - stage_src if pipelined else 0)
            if cfg.collect_timing:
                jax.block_until_ready(metrics["server_loss"])
                if rnd > start_round:             # skip the compile round
                    round_time += time.time() - t_round
                    timed_rounds += 1
            tracker.update(metrics)
            self._emit("on_round", rnd, state, metrics)
            if (rnd + 1) % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
                loss, mets = evaluate(self.task, state, self.fed)
                history.append({"round": rnd + 1, "test_loss": loss, **mets,
                                "train_loss": float(metrics["server_loss"]),
                                "elapsed_s": round(time.time() - t0, 1)})
                self.log(f"[{self.algo.name}] round {rnd+1:4d} "
                         f"test_loss={loss:.4f} "
                         f"{self.metric_key}="
                         f"{mets.get(self.metric_key, float('nan')):.4f}")
                if cfg.ckpt_dir:
                    save_checkpoint(cfg.ckpt_dir, rnd + 1, state,
                                    metadata={"algo": self.algo.name})
                self._emit("on_eval", rnd, loss, mets)
        result = {"algo": self.algo.name, "task": cfg.task,
                  "history": history, "grad_stability": tracker.summary()}
        tel = self._telemetry[t_tel:]
        if tel:
            result["telemetry"] = {
                "per_round": tel,
                "live_cohort_mean": float(np.mean([r["live"] for r in tel])),
                "dropped_total": int(sum(r["dropped"] for r in tel)),
                "drop_hazard_total": int(sum(r["drop_hazard"] for r in tel)),
                "drop_deadline_total": int(sum(r["drop_deadline"]
                                               for r in tel)),
                "max_realized_lag": max(r["realized_lag"] for r in tel),
                "max_drawn_lag": max(r["lag_drawn_max"] for r in tel),
            }
        if start_round:
            result["resumed_from_round"] = start_round
        if cfg.collect_timing:
            result["round_time_s"] = round_time / max(1, timed_rounds)
        if cfg.pipeline_depth > 0:
            self.pipeline_stats = {
                "active": pipelined if cfg.rounds > start_round else False,
                "mode": cfg.pipeline_staleness,
                "max_theta_s_lag_rounds": max_lag if pipelined else 0,
                "extract_traces": (self.pipeline.extract_traces
                                   if pipelined else 0),
                "tail_traces": (self.pipeline.tail_traces
                                if pipelined else 0),
            }
            result["pipeline"] = self.pipeline_stats
        return result
