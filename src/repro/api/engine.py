"""The ONE driver loop every entrypoint shares.

``Engine`` owns the cohort-sampling / round / eval / checkpoint cycle
that ``launch/train.py``, ``benchmarks/*``, and the examples used to
hand-roll: build (or accept) a task + federated dataset, compile the
algorithm's RoundProgram into a jitted round (TrainState buffers donated
off-CPU), then drive it for ``cfg.rounds`` rounds with the paper's
protocol (partial attendance, sample-wise eval split, fixed per-round
key stream).

Rounds are compile-once: every cohort is padded to the static capacity
``C_max = ceil(attendance * N)`` with an attendance mask threaded
through the round (see :mod:`repro.api.phases`), so the jitted round
traces exactly once per experiment no matter how live attendance varies
round to round — wall-clock measures the algorithm, not XLA retraces.

Mesh-native execution: with ``cfg.mesh_shape`` set the Engine builds
the device mesh ONCE, places the TrainState with ``NamedSharding`` (the
client stack's leading cohort dim over the batch axes, server weights
FSDP/TP per :mod:`repro.sharding.specs` path rules), commits every
round input to the batch axes, and pins the round's output shardings —
one trace per (algo, config, mesh), and the 1-device mesh is bit-for-
bit the unsharded path (constraints pin layout, never values).
``cfg.resume`` restores the latest checkpoint under ``ckpt_dir`` and
continues at the saved round with cadence and sampling stream aligned.

Pipelined rounds: ``cfg.pipeline_depth=L`` runs a software pipeline
over up to L+1 in-flight cohorts — cohorts k+1..k+L's ExtractFeatures
dispatches (batch axes) against cohort k's ServerUpdate..Commit tail
(model axes), with prefetched cohort sampling and an L-deep
:class:`~repro.core.feature_store.StaleFeatureRing` of buffered
:class:`~repro.api.phases.PipelineStage` stages.
``pipeline_staleness='sync'`` is bit-for-bit the sequential loop at any
depth (the ring degenerates to one barriered stage); ``'async'``
overlaps with at most L rounds of client/θ_S^t staleness, and
``cfg.staleness_weighting`` optionally scales each cohort's server and
feature gradients by its realized lag (see ARCHITECTURE.md "Pipelined
execution" and tests/test_pipeline.py).

Pluggable callbacks observe the loop without forking it::

    eng = Engine(ExperimentConfig(algo="cyclesfl", rounds=100))
    result = eng.run()           # {"history": [...], "grad_stability": ...}

Callbacks are any objects exposing ``on_round(engine, rnd, state,
metrics)`` and/or ``on_eval(engine, rnd, loss, mets)``.
"""
from __future__ import annotations

import math
import time
import warnings
from contextlib import nullcontext
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import ExperimentConfig
from repro.api.phases import (PipelinedAlgorithm, SLAlgorithm, TrainState,
                              build_algorithm, build_pipelined_algorithm,
                              init_train_state)
from repro.api.registry import get_program
from repro.api.tasks import build_task
from repro.checkpoint import (latest_step, load_checkpoint, load_metadata,
                              save_checkpoint)
from repro.core.drift import GradStabilityTracker
from repro.core.feature_store import StaleFeatureRing
from repro.core.split import SplitTask
from repro.data.federated import FederatedDataset, sample_cohort
from repro.launch.mesh import make_engine_mesh
from repro.optim import adam
from repro.resilience import (HEALTH_EMA, HEALTH_NONFINITE, HEALTH_SPIKE,
                              FaultInjectedError, RecoveryController,
                              ResilienceExhaustedError, build_fault_stream)
from repro.scenario.profiles import build_profile_stream
from repro.sharding.specs import batch_spec, train_state_shardings

_NULL_SECTION = nullcontext()     # reentrant no-op for unprofiled runs


def evaluate(task, state, fed, batch: int = 256, max_batches: int = 8,
             max_clients: int = 40):
    """Test metrics matching the paper's protocol (§4.1).

    SFL-family (global client model): pooled sample-wise test set.
    PSL-family (per-client models, never aggregated): per-client
    evaluation — each client's test samples are scored with THAT
    client's model, sample-weighted (a mean of unsynced client models
    is not a model anyone owns).
    """
    if state.client_global is not None:
        # pooled sample-wise test set: stack the full batches into ONE
        # vmapped device call, score the remainder in a second call, and
        # sync device->host once at the end (instead of a float() sync
        # per test batch, which serializes host and device)
        cp, sp = state.client_global.params, state.server.params
        # probe test_arrays() directly rather than scanning fed.clients
        # (which would materialize lazy population clients); with no
        # test data anywhere it raises on the empty concatenate
        try:
            xs, ys = fed.test_arrays()
        except ValueError:
            xs = ys = ()
        if not len(xs):
            warnings.warn("evaluate: pooled test set is empty; skipping "
                          "evaluation (NaN loss)", RuntimeWarning,
                          stacklevel=2)
            return float("nan"), {}
        n = min(len(xs), batch * max_batches)
        nfull, rem = divmod(n, batch)

        def one(x, y):
            out = task.predict(cp, sp, x)
            return task.loss(out, y), task.metrics(out, y)

        losses, mets, ws = [], [], []
        if nfull:
            xb = jnp.asarray(xs[:nfull * batch]).reshape(
                (nfull, batch) + xs.shape[1:])
            yb = jnp.asarray(ys[:nfull * batch]).reshape(
                (nfull, batch) + ys.shape[1:])
            lb, mb = jax.vmap(one)(xb, yb)
            losses.append(lb)
            mets.append(mb)
            ws += [batch] * nfull
        if rem:
            lr_, mr = one(jnp.asarray(xs[nfull * batch:n]),
                          jnp.asarray(ys[nfull * batch:n]))
            losses.append(jnp.reshape(lr_, (1,)))
            mets.append(jax.tree.map(lambda v: jnp.reshape(v, (1,)), mr))
            ws.append(rem)
        losses, mets = jax.device_get((jnp.concatenate(losses),
                                       {k: jnp.concatenate([m[k] for m in mets])
                                        for k in mets[0]}))
        agg = {k: float(np.average(v, weights=ws)) for k, v in mets.items()}
        return float(np.average(losses, weights=ws)), agg

    # per-client evaluation (vmapped: one trace, truncated to the common
    # test size so client stacks are rectangular)
    idxs = [i for i, c in enumerate(fed.clients) if len(c.x_test)][:max_clients]
    if not idxs:
        # no client holds test data (e.g. a train-only federation):
        # evaluation is undefined, not an error — report NaN and move on
        warnings.warn("evaluate: no sampled client has test data; "
                      "skipping per-client evaluation (NaN loss)",
                      RuntimeWarning, stacklevel=2)
        return float("nan"), {}
    t = min(len(fed.clients[i].x_test) for i in idxs)
    xs = jnp.asarray(np.stack([fed.clients[i].x_test[:t] for i in idxs]))
    ys = jnp.asarray(np.stack([fed.clients[i].y_test[:t] for i in idxs]))
    cps = jax.tree.map(lambda x: x[np.asarray(idxs)], state.clients.params)
    sp = state.server.params

    def one(cp, x, y):
        out = task.predict(cp, sp, x)
        return task.loss(out, y), task.metrics(out, y)

    losses, mets = jax.vmap(one)(cps, xs, ys)
    # one device->host sync for the whole eval (a float() per metric
    # would round-trip once per key)
    out = jax.device_get({"loss": jnp.mean(losses),
                          **{k: jnp.mean(v) for k, v in mets.items()}})
    return float(out["loss"]), {k: float(out[k]) for k in mets}


class Engine:
    """Compile once, drive the whole experiment."""

    def __init__(self, cfg: ExperimentConfig, *,
                 task: Optional[SplitTask] = None,
                 fed: Optional[FederatedDataset] = None,
                 metric_key: Optional[str] = None,
                 callbacks: Sequence = (),
                 donate: Optional[bool] = None,
                 profiler=None,
                 log=print):
        cfg.validate()
        if (task is None) != (fed is None):
            raise ValueError("pass BOTH task and fed (they come from one "
                             "generator) or neither")
        if task is None:
            task, fed, mk = build_task(cfg.task, cfg.n_clients, cfg.alpha,
                                       cfg.seed, cfg.width, cfg.cut)
            metric_key = metric_key or mk
        self.cfg = cfg
        self.task = task
        self.fed = fed
        self.metric_key = metric_key or "accuracy"
        self.callbacks = tuple(callbacks)
        self.log = log
        self.profiler = profiler
        if donate is None:
            # donation is supported on CPU too (run() threads the state
            # linearly, so it is SAFE), but aliasing changes XLA's
            # fusion choices at the ~1-ulp level, which would break the
            # bit-for-bit Engine goldens (pipelined == sequential,
            # mesh(1,1) == unsharded) that anchor this repo's
            # equivalence contracts.  Default it off on CPU; the
            # device-resident scaling path (bench workers, the CI
            # scaling leg) opts in with donate=True, and
            # tests/test_scaling.py pins the numerics it gets.
            donate = jax.default_backend() != "cpu"
        # ---- fault-tolerant runtime: the deterministic fault stream and
        # (per-run) recovery controller.  The null ResilienceConfig
        # builds neither and changes nothing downstream.  With recovery
        # active the TrainState buffers are NEVER donated — the pre-round
        # state and the snapshot ring must outlive every dispatch so a
        # faulted round can re-run from them.
        self.faults = build_fault_stream(cfg.resilience.faults, cfg.seed)
        self.recovery: Optional[RecoveryController] = None
        self._ema = None                  # loss-EMA carry (device scalar)
        self._ckpt_corruptions = 0
        if cfg.resilience.active:
            donate = False
        program = get_program(cfg.algo)
        opt_s, opt_c = adam(cfg.lr_server), adam(cfg.lr_client)
        # ---- mesh-native execution: build the mesh ONCE, derive the
        # TrainState placement from the path-regex rules (server weights
        # FSDP/TP, client stack's leading cohort dim over the batch
        # axes), and pin it as the jitted round's out_shardings so the
        # state sharding is stable round-over-round (compile-once per
        # (algo, config, mesh)).
        self.mesh = (make_engine_mesh(cfg.mesh_shape, cfg.mesh_axes)
                     if cfg.mesh_shape is not None else None)
        self.state_shardings = None
        if self.mesh is not None:
            a_state = jax.eval_shape(lambda: init_train_state(
                jax.random.PRNGKey(0), fed.n_clients, task, opt_s, opt_c,
                program.uses_global_client))
            self.state_shardings = train_state_shardings(
                a_state, self.mesh, shard_cohort=cfg.shard_cohort)
        # ---- client-population scenario: the profile stream feeding
        # per-round attendance weights + drop/lag events.  None for the
        # null scenario (kind='none') — every scenario branch below is
        # then skipped and the run is bit-for-bit scenario-free.
        self.scenario = build_profile_stream(cfg.scenario, fed.n_clients,
                                             cfg.seed)
        # resume-replay ledger window: draws for rounds below the cutoff
        # reconstruct the quarantine set the ORIGINAL run's sampler saw
        # at that round (from the persisted event history) instead of
        # the final restored set — see restore()
        self._ledger_cutoff = 0
        self._ledger_offset = 0
        self._sample_clock = 0            # rounds drawn so far (scenario
                                          # streams fold this in, resume
                                          # fast-forwards it)
        self._telemetry: list[dict] = []  # one row per sampled round
        # the θ staleness the schedule can realize: async pipelining at
        # depth L carries snapshots up to L rounds old; everything else
        # delivers fresh params (a straggler's *drawn* lag can exceed
        # this — its realized lag is capped by the schedule)
        self._sched_lag = (cfg.pipeline_depth
                           if cfg.pipeline_staleness == "async" else 0)
        churns = self.scenario is not None and self.scenario.churns
        if (cfg.pad_cohorts and (cfg.variable_attendance or churns)
                and any(getattr(p, "mode", None) == "cycle"
                        for p in program.phases)):
            # the masked inner loop's server batch is static; if it can
            # exceed the smallest possible live pool (min_cohort clients),
            # a low-attendance or churn-thinned round would fill ZERO
            # valid steps and the server would silently not train that
            # round — reject upfront
            sb = cfg.cycle.server_batch or cfg.batch
            if sb > cfg.batch * cfg.min_cohort:
                raise ValueError(
                    f"cycle.server_batch={sb} can exceed the smallest "
                    f"possible live feature pool (min_cohort={cfg.min_cohort}"
                    f" x batch={cfg.batch} = {cfg.min_cohort * cfg.batch} "
                    "rows) under variable attendance or scenario churn, "
                    "which would leave the server inner loop with zero "
                    "valid steps in sparse rounds; lower cycle.server_batch "
                    "or raise min_cohort")
        self.algo: SLAlgorithm = build_algorithm(
            program, task, opt_s, opt_c, cfg.cycle,
            donate=donate, mesh=self.mesh,
            state_shardings=self.state_shardings,
            shard_data=cfg.shard_cohort,
            resilience=cfg.resilience)
        # ---- pipelined rounds: compile the (extract, tail) dispatch
        # pair so cohort k+1's feature extraction can be in flight while
        # cohort k's server phase runs.  None for the fused sequential
        # programs (nothing to overlap) — the run loop falls back to the
        # monolithic round.  The TrainState is only donated into the
        # tail in sync mode: async mode keeps the pre-tail state alive
        # inside the next cohort's extract dispatch.
        self.pipeline: Optional[PipelinedAlgorithm] = None
        self.pipeline_stats: dict = {}
        if cfg.pipeline_depth > 0:
            self.pipeline = build_pipelined_algorithm(
                program, task, opt_s, opt_c, cfg.cycle,
                donate=donate,
                donate_state=(cfg.pipeline_staleness == "sync"),
                mesh=self.mesh, state_shardings=self.state_shardings,
                shard_data=cfg.shard_cohort,
                resilience=cfg.resilience,
                staleness_weighting=cfg.staleness_weighting,
                staleness_lambda=cfg.staleness_lambda,
                # deep rings buffer L stages across dispatch boundaries;
                # pin their placement (depth 1 keeps the PR-4 lowering)
                pin_stage=cfg.pipeline_depth > 1)
        if self.pipeline is None:
            # fused sequential programs fall back to monolithic rounds:
            # the schedule delivers fresh params whatever depth says
            self._sched_lag = 0

    @property
    def ring_depth(self) -> int:
        """In-flight extract stages the run loop keeps: the bounded
        staleness window L in async mode, one barriered stage in sync
        mode (any configured depth — sync extract(k+1) waits for
        Commit(k), so a deeper ring could never fill), 0 unpipelined."""
        if self.pipeline is None:
            return 0
        return self._sched_lag if self._sched_lag else 1

    # ------------------------------------------------------------ state
    def init_state(self) -> TrainState:
        state = self.algo.init(jax.random.PRNGKey(self.cfg.seed),
                               self.fed.n_clients)
        if self.state_shardings is not None:
            state = jax.device_put(state, self.state_shardings)
        return state

    def _place(self, arr):
        """Commit a [C, ...] round input to the mesh batch axes (leading
        cohort dim; no-op off-mesh or with cohort sharding disabled)."""
        x = jnp.asarray(arr)
        if self.mesh is None or not self.cfg.shard_cohort:
            return x
        from jax.sharding import NamedSharding
        spec = batch_spec(self.mesh, x.shape[0], x.ndim - 1)
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def round_key(self, rnd: int):
        return jax.random.PRNGKey(self.cfg.seed * self.cfg.round_key_salt
                                  + rnd)

    @property
    def cohort_capacity(self) -> int:
        """C_max: the static cohort shape every round is padded to.

        Deterministic attendance always draws exactly
        ``round(attendance * N)`` clients, so the capacity matches the
        sampler and no slot is ever padded; only variable attendance
        needs the ceil upper bound (Binomial draws above the mean are
        clipped to it).
        """
        cfg = self.cfg
        n = self.fed.n_clients
        if cfg.variable_attendance:
            # tolerant ceil: 0.3 * 20 is 6.000000000000001 in binary
            cap = math.ceil(cfg.attendance * n - 1e-9)
        else:
            cap = round(cfg.attendance * n)
        return min(max(cfg.min_cohort, cap), n)

    @property
    def padded_capacity(self) -> int:
        """The static cohort shape rounds are actually padded to:
        :attr:`cohort_capacity` rounded UP to a multiple of the mesh's
        batch-axis shard count, so every shard owns an equal slice of
        the slot dim (a ragged slot dim would make GSPMD pad the
        shard_map'd client phases with replicated compute).

        The SAMPLER still clips to the logical ``cohort_capacity``, so
        cohort draws are device-count-invariant; the alignment slots are
        always dead (sentinel id, zero mask) and every masked phase
        treats them exactly like attendance padding — numerics match the
        unaligned round bit-for-bit.  Identity off-mesh, at 1 device,
        and with cohort sharding disabled.
        """
        cap = self.cohort_capacity
        if self.mesh is None or not self.cfg.shard_cohort:
            return cap
        from repro.sharding.specs import shard_aligned_capacity
        return shard_aligned_capacity(self.mesh, cap)

    def _sample_cohort_ids(self, rng: np.random.Generator):
        """Draw one round's live cohort, advancing the sample clock.

        Called exactly once per round by both :meth:`sample_round` and
        :meth:`_replay_sampling`, so the clock (which time-varying
        scenario streams fold into their attendance weights) stays
        aligned across resume replays.  The null scenario contributes
        ``weights=None`` — ``rng.choice`` then takes the exact same
        draw path as the scenario-free Engine (bit-for-bit cohorts).
        """
        cfg = self.cfg
        rnd = self._sample_clock
        self._sample_clock = rnd + 1
        weights = (self.scenario.weights(rnd)
                   if self.scenario is not None else None)
        if self.recovery is not None:
            # quarantined clients draw weight 0 from here on; with no
            # quarantines this is a strict pass-through (None stays None,
            # so the null path keeps the exact scenario-free rng draws)
            ctl = self.recovery
            if rnd < self._ledger_cutoff:
                # resume replay: this draw happened BEFORE some of the
                # restored ledger's events — weight it with the set as
                # of its original draw time (pipelined runs draw one
                # round ahead of recovery, hence the offset)
                saved = ctl.quarantined
                ctl.quarantined = ctl.quarantined_as_of(
                    rnd - self._ledger_offset)
                weights = ctl.sampling_weights(weights)
                ctl.quarantined = saved
            else:
                weights = ctl.sampling_weights(weights)
        return sample_cohort(self.fed.n_clients, cfg.attendance, rng,
                             min_cohort=cfg.min_cohort,
                             variable=cfg.variable_attendance,
                             max_cohort=(self.cohort_capacity
                                         if cfg.pad_cohorts else None),
                             weights=weights)

    def _replay_sampling(self, rng: np.random.Generator, rounds: int):
        """Consume exactly the RNG draws ``rounds`` rounds of
        :meth:`sample_round` would make — cohort ids plus each member's
        batch indices — without materializing, padding, or placing any
        array.  Resume fast-forwards through this so round ``n`` of a
        resumed run draws the same cohort an uninterrupted run would."""
        for _ in range(rounds):
            for c in self._sample_cohort_ids(rng):
                self.fed.clients[c].sample_indices(rng, self.cfg.batch)

    def sample_round(self, rng: np.random.Generator):
        """Cohort ids, aligned per-client (x, y) batches, and the
        attendance mask for one round.

        With ``cfg.pad_cohorts`` (the default) the cohort is padded to
        the static :attr:`cohort_capacity`: padded slots carry the
        out-of-range sentinel id N (dropped by the commit scatter),
        zeroed batches, and a 0 in the mask — so the jitted round sees
        ONE shape for the whole experiment regardless of live
        attendance.  ``mask`` is ``None`` when padding is disabled.

        Scenario churn rides the same mask: a mid-round dropout (hazard
        draw, or a straggler whose drawn lag exceeds its staleness
        bound — a deadline miss) zeroes its LIVE slot, so its features
        never enter a valid server minibatch and its commit is skipped —
        exactly the padded-slot machinery, no new trace.  The client's
        batch is still drawn first, keeping the rng stream identical to
        a no-churn round.
        """
        cfg = self.cfg
        cap = self.padded_capacity if cfg.pad_cohorts else None
        cohort = self._sample_cohort_ids(rng)
        rnd = self._sample_clock - 1       # the round that draw was for
        live = len(cohort)
        pairs = [self.fed.clients[c].sample_batch(rng, cfg.batch)
                 for c in cohort]
        xs = np.stack([p[0] for p in pairs])
        ys = np.stack([p[1] for p in pairs])
        row = {"round": rnd, "cohort": live, "live": live, "dropped": 0,
               "drop_hazard": 0, "drop_deadline": 0, "lag_drawn_max": 0,
               "realized_lag": 0}
        if cap is None:
            self._telemetry.append(row)
            return (self._place(cohort), self._place(xs), self._place(ys),
                    None)
        pad = cap - live
        mask = np.ones(cap, np.float32)
        if pad:
            cohort = np.concatenate(
                [cohort, np.full(pad, self.fed.n_clients, cohort.dtype)])
            xs = np.concatenate([xs, np.zeros((pad,) + xs.shape[1:],
                                              xs.dtype)])
            ys = np.concatenate([ys, np.zeros((pad,) + ys.shape[1:],
                                              ys.dtype)])
            mask[-pad:] = 0.0
        if self.scenario is not None and self.scenario.churns:
            ev = self.scenario.events(rnd, cohort[:live],
                                      min_live=cfg.min_cohort)
            mask[:live] *= ev.keep
            kept = int(ev.keep.sum())
            row.update(live=kept, dropped=live - kept,
                       drop_hazard=ev.hazard_drops,
                       drop_deadline=ev.deadline_drops,
                       lag_drawn_max=int(ev.lag.max()) if live else 0)
        self._telemetry.append(row)
        return (self._place(cohort), self._place(xs), self._place(ys),
                self._place(mask))

    def _emit(self, hook: str, *args):
        for cb in self.callbacks:
            fn = getattr(cb, hook, None)
            if fn is not None:
                fn(self, *args)

    # ---------------------------------------------------------- resume
    def restore(self, rng: np.random.Generator
                ) -> tuple[Optional[TrainState], int]:
        """Load the latest checkpoint under ``cfg.ckpt_dir`` and return
        ``(state, start_round)``; ``(None, 0)`` when nothing to resume.

        The checkpoint step is the 1-based round it was saved after, so
        the run continues at exactly that round index and the eval/ckpt
        cadence (``(rnd + 1) % eval_every``) stays aligned.  The cohort-
        sampling stream is replayed through the skipped rounds so round
        ``start_round`` draws the same cohort an uninterrupted run would
        have drawn.
        """
        cfg = self.cfg
        step = latest_step(cfg.ckpt_dir) if cfg.ckpt_dir else None
        if step is None:
            return None, 0
        # structure/dtype template only — no init compute or placement
        template = jax.eval_shape(
            lambda: self.algo.init(jax.random.PRNGKey(cfg.seed),
                                   self.fed.n_clients))
        state, _ = load_checkpoint(cfg.ckpt_dir, template, step=step)
        if self.state_shardings is not None:
            state = jax.device_put(state, self.state_shardings)
        if self.recovery is not None:
            # restore the recovery carry BEFORE replaying the sampling
            # stream: replay reconstructs the per-round quarantine set
            # from the persisted event history, so the replayed draws
            # consume exactly the variates the original run's did
            # (rng.choice with weights takes a different draw path than
            # without).  Older checkpoints without the key keep the
            # fresh controller (their runs had nothing to remember).
            meta = load_metadata(cfg.ckpt_dir, step).get("resilience")
            if meta:
                self.recovery.restore_state(meta)
                if "ema" in meta:
                    self._ema = jnp.asarray(meta["ema"], jnp.float32)
            # pipelined runs draw round r's cohort ring_depth loop
            # iterations early (before rounds r-L..r-1's recovery), so
            # their draws trail the ledger by ring_depth rounds —
            # including the post-replay priming draws for rounds
            # `step..step+L-1` themselves
            self._ledger_offset = self.ring_depth
            self._ledger_cutoff = step + self._ledger_offset
        self._replay_sampling(rng, step)
        self.log(f"[{self.algo.name}] resumed from {cfg.ckpt_dir} at "
                 f"round {step}")
        return state, step

    # --------------------------------------------------------- pipeline
    def _extract(self, state, inputs):
        """Dispatch the ExtractFeatures head for one cohort."""
        cohort, xs, ys, mask = inputs
        if mask is None:
            return self.pipeline.extract(state, cohort, xs, ys)
        return self.pipeline.extract(state, cohort, xs, ys, mask)

    def _tail(self, state, inputs, stage, key, lag: int = 0):
        """Dispatch the ServerUpdate..Commit tail consuming ``stage``."""
        cohort, xs, ys, mask = inputs
        kw = {}
        if self.cfg.staleness_weighting != "none":
            # the realized lag rides in as a TRACED f32 scalar so one
            # tail trace serves every lag the ring can deliver; with
            # weighting 'none' the call keeps its exact historical
            # signature (bit-for-bit the pre-weighting trace)
            kw["lag"] = jnp.float32(lag)
        if self.cfg.resilience.guard:
            # guard-on rounds ALWAYS thread the EMA carry, so the tail
            # compiles once with the health phase folded in
            return self.pipeline.tail(state, cohort, xs, ys, key, stage,
                                      mask, self._ema, **kw)
        if mask is None:
            return self.pipeline.tail(state, cohort, xs, ys, key, stage,
                                      **kw)
        return self.pipeline.tail(state, cohort, xs, ys, key, stage, mask,
                                  **kw)

    def _round_call(self, state, inputs, key):
        """Dispatch the monolithic round (guard-off calls keep the exact
        historical signature, so the trace is bit-for-bit unchanged)."""
        cohort, xs, ys, mask = inputs
        if self.cfg.resilience.guard:
            return self.algo.round(state, cohort, xs, ys, key, mask,
                                   self._ema)
        if mask is None:
            return self.algo.round(state, cohort, xs, ys, key)
        return self.algo.round(state, cohort, xs, ys, key, mask)

    # ------------------------------------------------------- resilience
    def _inject_nan(self, inputs, rnd: int, attempt: int):
        """Fault hook: poison the drawn cohort's input batches with NaN
        per the deterministic stream (no-op without one)."""
        if self.faults is None or inputs is None:
            return inputs
        cohort, xs, ys, mask = inputs
        if not jnp.issubdtype(jnp.asarray(xs).dtype, jnp.inexact):
            return inputs
        live = int((np.asarray(cohort) < self.fed.n_clients).sum())
        slots = self.faults.nan_slots_for(rnd, attempt, live)
        if slots.size == 0:
            return inputs
        xs = self._place(jnp.asarray(xs).at[jnp.asarray(slots)]
                         .set(jnp.nan))
        self.log(f"[resilience] round {rnd} attempt {attempt}: injected "
                 f"NaN features in slots {slots.tolist()}")
        return (cohort, xs, ys, mask)

    def _verdict(self, metrics) -> Optional[str]:
        """Host-read the packed health vector — the ONE sync the guard
        costs per round.  Returns the fault kind or None (healthy)."""
        if not self.cfg.resilience.guard:
            return None
        h = jax.device_get(metrics["health"])
        if h[HEALTH_NONFINITE] > 0:
            return "nonfinite"
        if h[HEALTH_SPIKE] > 0 and self.recovery.spike_armed():
            return "spike"
        return None

    def _recover_round(self, state, inputs, inj0, rnd: int, stage=None,
                       pipelined: bool = False, lag: int = 0):
        """Drive round ``rnd`` to an accepted ``(state, metrics)`` under
        the recovery policy.

        ``inputs`` are the CLEAN sampled round inputs; ``inj0`` the
        attempt-0 fault-injected view of them (identical objects when no
        fault fired).  ``stage`` is the already-dispatched extract for
        ``inj0`` on the pipelined path — recovery attempts re-extract
        from the current candidate state, because the pooled store bakes
        the attendance mask in at extract time.

        Returns ``(state, metrics, attempts, healthy)``; raises
        :class:`ResilienceExhaustedError` past ``max_retries`` and lets
        an injected error escape unhandled only when every fallback
        action is exhausted.
        """
        ctl, rcfg = self.recovery, self.cfg.resilience
        key = self.round_key(rnd)
        cur_state, cur_inputs, cur_inj, cur_stage = state, inputs, inj0, stage
        kinds: list[str] = []
        actions: list[str] = []
        attempt = 0
        while True:
            site = ("extract" if pipelined and cur_stage is None
                    else ("tail" if pipelined else "round"))
            try:
                if self.faults is not None:
                    self.faults.check_dispatch(rnd, attempt, site)
                if pipelined:
                    # a re-extract reads the CURRENT candidate state, so
                    # its realized lag (and staleness weight) resets to 0
                    st, att_lag = cur_stage, lag
                    if st is None:
                        st, att_lag = self._extract(cur_state, cur_inj), 0
                    new_state, metrics = self._tail(cur_state, cur_inj,
                                                    st, key, lag=att_lag)
                else:
                    new_state, metrics = self._round_call(cur_state,
                                                          cur_inj, key)
                kind = self._verdict(metrics)
            except FaultInjectedError as e:
                self.log(f"[resilience] {e}")
                kind, new_state, metrics = "error", None, None
            if kind is None:
                break                      # healthy — accept
            kinds.append(kind)
            if len(kinds) > rcfg.max_retries:
                ctl.record_round(rnd, len(kinds), kinds, actions,
                                 len(ctl.quarantined))
                raise ResilienceExhaustedError(rnd, len(kinds), kinds)
            # resolve the configured action, escalating past the ones
            # that cannot apply (no blamable slot, empty snapshot ring)
            action = ctl.action_for(kind, attempt)
            applied = None
            while applied is None:
                if action == "ignore" and new_state is not None:
                    applied = "ignore"
                elif action == "quarantine":
                    mask = cur_inputs[3]
                    sb = (metrics.get("health_slot_bad")
                          if metrics is not None else None)
                    nm = (ctl.quarantine(np.asarray(cur_inputs[0]),
                                         np.asarray(mask), np.asarray(sb),
                                         rnd=rnd)
                          if mask is not None and sb is not None else None)
                    if nm is not None:
                        placed = self._place(nm)
                        cur_inputs = cur_inputs[:3] + (placed,)
                        cur_inj = cur_inj[:3] + (placed,)
                        applied = "quarantine"
                elif action == "retry":
                    applied = "retry"
                elif action == "rollback":
                    tgt = ctl.rollback()
                    if tgt is not None:
                        _, cur_state, self._ema = tgt
                        applied = "rollback"
                if applied is None:
                    nxt = ctl.escalate(action) if action else None
                    if nxt is None:
                        applied = "retry"  # last resort
                    else:
                        action = nxt
            actions.append(applied)
            if applied == "ignore":
                self.log(f"[resilience] round {rnd}: {kind} ignored "
                         "by policy")
                break
            self.log(f"[resilience] round {rnd}: {kind} -> {applied} "
                     f"(attempt {len(kinds)}/{rcfg.max_retries})")
            ctl.backoff(len(kinds))
            attempt += 1
            cur_stage = None               # stale: mask/state may differ
            cur_inj = self._inject_nan(cur_inputs, rnd, attempt)
        healthy = kind is None
        ctl.record_round(rnd, len(kinds), kinds, actions,
                         len(ctl.quarantined))
        return new_state, metrics, len(kinds), healthy

    # -------------------------------------------------------------- run
    def run(self, state: Optional[TrainState] = None) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 1)
        if cfg.resilience.active:
            # fresh controller per run: empty quarantine ledger, empty
            # snapshot ring, EMA at the unarmed sentinel.  Built BEFORE
            # any sampling so resume replays see the same (empty) ledger
            # the original run started with.
            self.recovery = RecoveryController(
                cfg.resilience, self.fed.n_clients,
                min_live=cfg.min_cohort, log=self.log)
            self._ema = jnp.zeros((), jnp.float32)
            self._ckpt_corruptions = 0
        start_round = 0
        if state is None and cfg.resume:
            state, start_round = self.restore(rng)
        if state is None:
            state = self.init_state()
        elif self.state_shardings is not None:
            # caller-provided (or restored) states must sit on the mesh
            # placement the jitted round's out_shardings pin, or round 1
            # would see a different input sharding than round 0 and
            # retrace — no-op when already placed
            state = jax.device_put(state, self.state_shardings)
        tracker = GradStabilityTracker()
        history = []
        round_time, timed_rounds = 0.0, 0
        t0 = time.time()
        prof = self.profiler
        sec = (prof.section if prof is not None
               else (lambda name: _NULL_SECTION))
        # telemetry sync cadence: the host blocks on round metrics only
        # at window boundaries (compile round, every sync_k-th round,
        # the last round) — in between, rounds dispatch back-to-back and
        # stay device-resident.  The resilience guard host-reads the
        # health verdict every round by design, so it pins sync_k to 1.
        sync_k = 1 if cfg.resilience.guard else max(1, cfg.sync_every)
        t_mark, r_mark = t0, start_round
        # ---- pipeline prime: sample the first ``ring_depth`` cohorts IN
        # ROUND ORDER (the rng/cohort stream stays bit-for-bit the
        # sequential one) and put their extractions in flight from the
        # initial state (async dispatches — they do not block the host).
        # Consumed at lags 0..L-1, under the L bound by construction.
        # On resume the restored state re-primes the ring, so every
        # post-resume stage reads the restored (fresh) params, exactly
        # like the uninterrupted run's warm-up rounds.
        pipelined = self.pipeline is not None
        ring_depth = self.ring_depth
        t_tel = len(self._telemetry)     # rows this run will append start here
        ring = StaleFeatureRing(ring_depth) if pipelined else None
        max_lag, cur_lag = 0, 0
        nxt_inputs = None                # non-pipelined double buffer
        if pipelined:
            for i in range(min(ring_depth, cfg.rounds - start_round)):
                p_inputs = self.sample_round(rng)
                # attempt-0 fault injection happens BEFORE the priming
                # extract so a poisoned delivery flows into the stage's
                # features (no-op without a fault stream)
                p_inj = self._inject_nan(p_inputs, start_round + i, 0)
                ring.push(start_round + i, start_round,
                          self._extract(state, p_inj), p_inputs, p_inj)
        for rnd in range(start_round, cfg.rounds):
            attempts, healthy = 0, True
            if pipelined:
                # host-side bookkeeping only: round k's stage leaves the
                # ring before the k+L slot is pushed, so at most L stages
                # are ever buffered and every consumed lag is <= L
                entry = ring.pop(rnd)
                inputs, inj_inputs = entry.inputs, entry.inj_inputs
                cur_lag = rnd - entry.src_round
                max_lag = max(max_lag, cur_lag)
                # prefetch cohort k+L's sampling while round k's compute
                # is (or is about to be) on the devices
                with sec("sample"):
                    nxt_inputs = (self.sample_round(rng)
                                  if rnd + ring_depth < cfg.rounds else None)
                nxt_inj = (self._inject_nan(nxt_inputs, rnd + ring_depth, 0)
                           if nxt_inputs is not None else None)
                t_round = time.time()
                if nxt_inputs is not None \
                        and cfg.pipeline_staleness == "async":
                    # overlap: extract(k+L) from the PRE-tail state — it
                    # shares no dependency with tail(k)'s outputs, so XLA
                    # can run it on the batch axes while the server inner
                    # loop occupies the model axes.  Clients and the
                    # θ_S^t snapshot are stale by exactly L rounds once
                    # the ring is warm (less during warm-up and rewinds).
                    ring.push(rnd + ring_depth, rnd,
                              self._extract(state, nxt_inj),
                              nxt_inputs, nxt_inj)
                if self.recovery is None:
                    with sec("dispatch"):
                        state, metrics = self._tail(state, inj_inputs,
                                                    entry.stage,
                                                    self.round_key(rnd),
                                                    lag=cur_lag)
                else:
                    state, metrics, attempts, healthy = self._recover_round(
                        state, inputs, inj_inputs, rnd, stage=entry.stage,
                        pipelined=True, lag=cur_lag)
                    if attempts and len(ring):
                        # every in-flight prefetch read a pre-round state
                        # that recovery discarded — re-extract the whole
                        # ring from the accepted state, deterministically
                        # rewinding the schedule (the rewound stages are
                        # fresh: their lags restart from 0)
                        ring.rewind(lambda inj: self._extract(state, inj),
                                    src_round=rnd + 1)
                if nxt_inputs is not None \
                        and cfg.pipeline_staleness != "async":
                    # sync barrier: extract(k+1) reads the post-Commit
                    # state — bit-for-bit the sequential schedule
                    ring.push(rnd + 1, rnd + 1,
                              self._extract(state, nxt_inj),
                              nxt_inputs, nxt_inj)
            else:
                with sec("sample"):
                    # double buffer: round k-1 already sampled, padded,
                    # and device_put this round's inputs while round
                    # k-1's compute was in flight
                    inputs = (nxt_inputs if nxt_inputs is not None
                              else self.sample_round(rng))
                    nxt_inputs = None
                t_round = time.time()
                if self.recovery is None:
                    with sec("dispatch"):
                        state, metrics = self._round_call(
                            state, inputs, self.round_key(rnd))
                    if rnd + 1 < cfg.rounds:
                        # prefetch cohort k+1 behind the in-flight round
                        # (device_put is async; nothing here blocks)
                        with sec("sample"):
                            nxt_inputs = self.sample_round(rng)
                else:
                    # recovery may re-draw quarantine weights mid-round,
                    # so the faulted path samples strictly per round
                    inj = self._inject_nan(inputs, rnd, 0)
                    state, metrics, attempts, healthy = \
                        self._recover_round(state, inputs, inj, rnd)
            if self.recovery is not None and cfg.resilience.guard:
                # thread the EMA carry forward and snapshot last-good
                # states — both stay on device (no extra host sync)
                self._ema = metrics["health"][HEALTH_EMA]
                if healthy:
                    self.recovery.note_accept(rnd, state, self._ema)
            # telemetry rows are appended at sample time (for pipelined
            # runs that's one round AHEAD of the tail); the θ staleness a
            # round actually saw is only known here, once its tail ran
            ti = t_tel + (rnd - start_round)
            if ti < len(self._telemetry):
                self._telemetry[ti]["realized_lag"] = (
                    cur_lag if pipelined else 0)
            if cfg.collect_timing:
                if sync_k == 1:
                    with sec("sync"):
                        jax.block_until_ready(metrics["server_loss"])
                    if rnd > start_round:         # skip the compile round
                        round_time += time.time() - t_round
                        timed_rounds += 1
                elif rnd == start_round:
                    # compile round: sync it out of the first window
                    with sec("sync"):
                        jax.block_until_ready(metrics["server_loss"])
                    t_mark, r_mark = time.time(), rnd + 1
                elif (rnd == cfg.rounds - 1
                      or (rnd + 1 - start_round) % sync_k == 0):
                    # window boundary: one sync covers the whole window,
                    # timing averages over its rounds
                    with sec("sync"):
                        jax.block_until_ready(metrics["server_loss"])
                    round_time += time.time() - t_mark
                    timed_rounds += rnd + 1 - r_mark
                    t_mark, r_mark = time.time(), rnd + 1
            tracker.update(metrics)
            self._emit("on_round", rnd, state, metrics)
            if (rnd + 1) % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
                with sec("eval"):
                    loss, mets = evaluate(self.task, state, self.fed)
                history.append({"round": rnd + 1, "test_loss": loss, **mets,
                                "train_loss": float(metrics["server_loss"]),
                                "elapsed_s": round(time.time() - t0, 1)})
                self.log(f"[{self.algo.name}] round {rnd+1:4d} "
                         f"test_loss={loss:.4f} "
                         f"{self.metric_key}="
                         f"{mets.get(self.metric_key, float('nan')):.4f}")
                if cfg.ckpt_dir:
                    meta = {"algo": self.algo.name}
                    if self.recovery is not None:
                        # persist the recovery carry a resumed run must
                        # not forget: the quarantine ledger (+ replayable
                        # event history) and the spike-EMA scalar
                        # (fp32 -> python float -> fp32 is exact)
                        meta["resilience"] = {
                            **self.recovery.export_state(),
                            "ema": float(jax.device_get(self._ema)),
                        }
                    save_checkpoint(cfg.ckpt_dir, rnd + 1, state,
                                    metadata=meta)
                    if self.faults is not None \
                            and self.faults.ckpt_corrupt(rnd + 1):
                        # tear the just-written step: restore must fall
                        # back past it to the newest valid one
                        self.faults.corrupt_checkpoint(cfg.ckpt_dir,
                                                       rnd + 1)
                        self._ckpt_corruptions += 1
                        self.log(f"[resilience] injected torn checkpoint "
                                 f"at step {rnd + 1}")
                self._emit("on_eval", rnd, loss, mets)
        result = {"algo": self.algo.name, "task": cfg.task,
                  "history": history, "grad_stability": tracker.summary()}
        tel = self._telemetry[t_tel:]
        if tel:
            result["telemetry"] = {
                "per_round": tel,
                "live_cohort_mean": float(np.mean([r["live"] for r in tel])),
                "dropped_total": int(sum(r["dropped"] for r in tel)),
                "drop_hazard_total": int(sum(r["drop_hazard"] for r in tel)),
                "drop_deadline_total": int(sum(r["drop_deadline"]
                                               for r in tel)),
                "max_realized_lag": max(r["realized_lag"] for r in tel),
                "max_drawn_lag": max(r["lag_drawn_max"] for r in tel),
            }
        if self.recovery is not None:
            summary = self.recovery.summary()
            summary["ckpt_corruptions"] = self._ckpt_corruptions
            result["resilience"] = summary
        if start_round:
            result["resumed_from_round"] = start_round
        if cfg.collect_timing:
            result["round_time_s"] = round_time / max(1, timed_rounds)
        if cfg.pipeline_depth > 0:
            self.pipeline_stats = {
                "active": pipelined if cfg.rounds > start_round else False,
                "mode": cfg.pipeline_staleness,
                "depth": cfg.pipeline_depth,
                "ring_depth": ring_depth,
                "staleness_weighting": cfg.staleness_weighting,
                "max_theta_s_lag_rounds": max_lag if pipelined else 0,
                "realized_lags": (list(ring.realized_lags)
                                  if ring is not None else []),
                "extract_traces": (self.pipeline.extract_traces
                                   if pipelined else 0),
                "tail_traces": (self.pipeline.tail_traces
                                if pipelined else 0),
            }
            result["pipeline"] = self.pipeline_stats
        if prof is not None:
            result["profile"] = prof.summary()
        return result
