"""The ONE driver loop every entrypoint shares.

``Engine`` owns the cohort-sampling / round / eval / checkpoint cycle
that ``launch/train.py``, ``benchmarks/*``, and the examples used to
hand-roll: build (or accept) a task + federated dataset, compile the
algorithm's RoundProgram into a jitted round (TrainState buffers donated
off-CPU), then drive it for ``cfg.rounds`` rounds with the paper's
protocol (partial attendance, sample-wise eval split, fixed per-round
key stream).

Pluggable callbacks observe the loop without forking it::

    eng = Engine(ExperimentConfig(algo="cyclesfl", rounds=100))
    result = eng.run()           # {"history": [...], "grad_stability": ...}

Callbacks are any objects exposing ``on_round(engine, rnd, state,
metrics)`` and/or ``on_eval(engine, rnd, loss, mets)``.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import ExperimentConfig
from repro.api.phases import SLAlgorithm, TrainState, build_algorithm
from repro.api.registry import get_program
from repro.api.tasks import build_task
from repro.checkpoint import save_checkpoint
from repro.core.drift import GradStabilityTracker
from repro.core.split import SplitTask
from repro.data.federated import FederatedDataset, sample_cohort
from repro.optim import adam


def evaluate(task, state, fed, batch: int = 256, max_batches: int = 8,
             max_clients: int = 40):
    """Test metrics matching the paper's protocol (§4.1).

    SFL-family (global client model): pooled sample-wise test set.
    PSL-family (per-client models, never aggregated): per-client
    evaluation — each client's test samples are scored with THAT
    client's model, sample-weighted (a mean of unsynced client models
    is not a model anyone owns).
    """
    if state.client_global is not None:
        cp = state.client_global.params
        xs, ys = fed.test_arrays()
        n = min(len(xs), batch * max_batches)
        losses, mets, ws = [], [], []
        for i in range(0, n, batch):
            out = task.predict(cp, state.server.params,
                               jnp.asarray(xs[i:i + batch]))
            losses.append(float(task.loss(out, jnp.asarray(ys[i:i + batch]))))
            mets.append({k: float(v) for k, v in
                         task.metrics(out, jnp.asarray(ys[i:i + batch])).items()})
            ws.append(len(xs[i:i + batch]))
        agg = {k: float(np.average([m[k] for m in mets], weights=ws))
               for k in mets[0]}
        return float(np.average(losses, weights=ws)), agg

    # per-client evaluation (vmapped: one trace, truncated to the common
    # test size so client stacks are rectangular)
    idxs = [i for i, c in enumerate(fed.clients) if len(c.x_test)][:max_clients]
    t = min(len(fed.clients[i].x_test) for i in idxs)
    xs = jnp.asarray(np.stack([fed.clients[i].x_test[:t] for i in idxs]))
    ys = jnp.asarray(np.stack([fed.clients[i].y_test[:t] for i in idxs]))
    cps = jax.tree.map(lambda x: x[np.asarray(idxs)], state.clients.params)
    sp = state.server.params

    def one(cp, x, y):
        out = task.predict(cp, sp, x)
        return task.loss(out, y), task.metrics(out, y)

    losses, mets = jax.vmap(one)(cps, xs, ys)
    agg = {k: float(jnp.mean(v)) for k, v in mets.items()}
    return float(jnp.mean(losses)), agg


class Engine:
    """Compile once, drive the whole experiment."""

    def __init__(self, cfg: ExperimentConfig, *,
                 task: Optional[SplitTask] = None,
                 fed: Optional[FederatedDataset] = None,
                 metric_key: Optional[str] = None,
                 callbacks: Sequence = (),
                 donate: Optional[bool] = None,
                 log=print):
        cfg.validate()
        if (task is None) != (fed is None):
            raise ValueError("pass BOTH task and fed (they come from one "
                             "generator) or neither")
        if task is None:
            task, fed, mk = build_task(cfg.task, cfg.n_clients, cfg.alpha,
                                       cfg.seed, cfg.width, cfg.cut)
            metric_key = metric_key or mk
        self.cfg = cfg
        self.task = task
        self.fed = fed
        self.metric_key = metric_key or "accuracy"
        self.callbacks = tuple(callbacks)
        self.log = log
        if donate is None:
            # buffer donation is a no-op XLA warning on CPU; enable elsewhere
            donate = jax.default_backend() != "cpu"
        self.algo: SLAlgorithm = build_algorithm(
            get_program(cfg.algo), task,
            adam(cfg.lr_server), adam(cfg.lr_client), cfg.cycle,
            donate=donate)

    # ------------------------------------------------------------ state
    def init_state(self) -> TrainState:
        return self.algo.init(jax.random.PRNGKey(self.cfg.seed),
                              self.fed.n_clients)

    def round_key(self, rnd: int):
        return jax.random.PRNGKey(self.cfg.seed * self.cfg.round_key_salt
                                  + rnd)

    def sample_round(self, rng: np.random.Generator):
        """Cohort ids + aligned per-client (x, y) batches for one round."""
        cfg = self.cfg
        cohort = sample_cohort(self.fed.n_clients, cfg.attendance, rng,
                               min_cohort=cfg.min_cohort)
        pairs = [self.fed.clients[c].sample_batch(rng, cfg.batch)
                 for c in cohort]
        xs = jnp.asarray(np.stack([p[0] for p in pairs]))
        ys = jnp.asarray(np.stack([p[1] for p in pairs]))
        return cohort, xs, ys

    def _emit(self, hook: str, *args):
        for cb in self.callbacks:
            fn = getattr(cb, hook, None)
            if fn is not None:
                fn(self, *args)

    # -------------------------------------------------------------- run
    def run(self, state: Optional[TrainState] = None) -> dict:
        cfg = self.cfg
        state = self.init_state() if state is None else state
        rng = np.random.default_rng(cfg.seed + 1)
        tracker = GradStabilityTracker()
        history = []
        round_time = 0.0
        t0 = time.time()
        for rnd in range(cfg.rounds):
            cohort, xs, ys = self.sample_round(rng)
            t_round = time.time()
            state, metrics = self.algo.round(state, jnp.asarray(cohort),
                                             xs, ys, self.round_key(rnd))
            if cfg.collect_timing:
                jax.block_until_ready(metrics["server_loss"])
                if rnd > 0:                       # skip the compile round
                    round_time += time.time() - t_round
            tracker.update(metrics)
            self._emit("on_round", rnd, state, metrics)
            if (rnd + 1) % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
                loss, mets = evaluate(self.task, state, self.fed)
                history.append({"round": rnd + 1, "test_loss": loss, **mets,
                                "train_loss": float(metrics["server_loss"]),
                                "elapsed_s": round(time.time() - t0, 1)})
                self.log(f"[{self.algo.name}] round {rnd+1:4d} "
                         f"test_loss={loss:.4f} "
                         f"{self.metric_key}="
                         f"{mets.get(self.metric_key, float('nan')):.4f}")
                if cfg.ckpt_dir:
                    save_checkpoint(cfg.ckpt_dir, rnd + 1, state,
                                    metadata={"algo": self.algo.name})
                self._emit("on_eval", rnd, loss, mets)
        result = {"algo": self.algo.name, "task": cfg.task,
                  "history": history, "grad_stability": tracker.summary()}
        if cfg.collect_timing:
            result["round_time_s"] = round_time / max(1, cfg.rounds - 1)
        return result
