"""One registry for the SL algorithm zoo (paper §2.1 / §4).

Every algorithm is a :class:`RoundProgram` — the declarative phase
composition defined in :mod:`repro.api.phases`.  The table makes the
paper's "seamless integration" claim auditable: each Cycle variant is
its baseline with ``ServerUpdate`` swapped to the CycleSL inner loop
and ``FeatureGradients`` pointed at the updated server.

New algorithms register here (``register_program``) and immediately
work in every driver: ``Engine``, ``launch/train.py``, the benchmark
harness, and the deprecated ``make_algorithm`` shim.
"""
from __future__ import annotations

from repro.api.phases import (ClientUpdate, Commit, ExtractFeatures,
                              FeatureGradients, LocalFedAvgRound,
                              RoundProgram, SequentialChainRound,
                              ServerSequentialRound, ServerUpdate)


def _classic(name: str, server_mode: str, commit: str,
             average: bool | None = False) -> RoundProgram:
    """Classic SL order: features -> server step(s) -> gradients at the
    PRE-update server θ_S^t -> client VJP steps -> commit."""
    return RoundProgram(name, (
        ExtractFeatures(),
        ServerUpdate(mode=server_mode),
        FeatureGradients(use_updated=False, average=average),
        ClientUpdate(),
        Commit(mode=commit),
    ), uses_global_client=(commit == "average"))


def _cycle(name: str, commit: str,
           average: bool | None = None) -> RoundProgram:
    """CycleSL order (Algorithm 1): the server trains FIRST on the pooled
    feature dataset, clients then receive gradients from the UPDATED,
    frozen server (Eq. 5)."""
    return RoundProgram(name, (
        ExtractFeatures(),
        ServerUpdate(mode="cycle"),
        FeatureGradients(use_updated=True, average=average),
        ClientUpdate(record_gnorm=True),
        Commit(mode=commit),
    ), uses_global_client=(commit == "average"))


PROGRAMS: dict[str, RoundProgram] = {
    # sequential / fused baselines
    "ssl": RoundProgram("ssl", (SequentialChainRound(),),
                        uses_global_client=True),
    "sflv2": RoundProgram("sflv2", (ServerSequentialRound(),),
                          uses_global_client=True),
    "fedavg": RoundProgram("fedavg", (LocalFedAvgRound(),),
                           uses_global_client=True),
    # parallel SL family (classic back-prop order)
    "psl": _classic("psl", "replica_avg", commit="per_client"),
    "sflv1": _classic("sflv1", "replica_avg", commit="average"),
    "sglr": _classic("sglr", "mean_grad", commit="per_client", average=True),
    # Cycle variants: same programs, server phase swapped
    "cyclepsl": _cycle("cyclepsl", commit="per_client"),
    "cyclesfl": _cycle("cyclesfl", commit="average"),
    "cyclesglr": _cycle("cyclesglr", commit="per_client", average=True),
    # CycleSL on the sequential chain (appendix-only in the paper): one
    # shared client model updated along the cohort chain
    "cyclessl": RoundProgram("cyclessl", (
        ExtractFeatures(),
        ServerUpdate(mode="cycle"),
        FeatureGradients(use_updated=True),
        ClientUpdate(record_gnorm=True, chained=True),
        Commit(mode="global"),
    ), uses_global_client=True),
}


def get_program(name: str) -> RoundProgram:
    key = name.lower()
    if key not in PROGRAMS:
        raise KeyError(f"unknown algorithm {name!r}: {sorted(PROGRAMS)}")
    return PROGRAMS[key]


def register_program(program: RoundProgram, overwrite: bool = False) -> None:
    key = program.name.lower()          # lookups lowercase; store likewise
    if key in PROGRAMS and not overwrite:
        raise ValueError(f"algorithm {key!r} already registered "
                         "(pass overwrite=True to replace)")
    PROGRAMS[key] = program


def algorithm_names() -> tuple[str, ...]:
    return tuple(sorted(PROGRAMS))
