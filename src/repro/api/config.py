"""Frozen experiment description + builders (``from_dict``/``from_flags``).

One :class:`ExperimentConfig` captures everything a run needs — the
algorithm name (resolved through the program registry), the task name
(resolved through the task registry), cohort/protocol knobs, the nested
:class:`CycleConfig`, and eval/checkpoint cadence — and round-trips
losslessly through ``to_dict``/``from_dict`` so configs can live in JSON
sweep files.
"""
from __future__ import annotations

import argparse
from dataclasses import asdict, dataclass, field, fields, replace
from typing import Optional

from repro.core.cyclesl import CycleConfig
from repro.resilience.config import ResilienceConfig
from repro.serve.config import ServeConfig
from repro.scenario.profiles import ScenarioConfig


@dataclass(frozen=True)
class ExperimentConfig:
    algo: str = "cyclesfl"
    task: str = "image"
    rounds: int = 100
    n_clients: int = 100
    attendance: float = 0.05          # partial participation rate (§4.1)
    min_cohort: int = 2
    batch: int = 16
    lr_server: float = 1e-3
    lr_client: float = 1e-3
    alpha: float = 0.5                # Dirichlet label-skew strength
    seed: int = 0
    width: int = 16
    cut: int = 2
    eval_every: int = 20
    ckpt_dir: Optional[str] = None
    # per-round PRNG stream: key = PRNGKey(seed * round_key_salt + round)
    round_key_salt: int = 100_000
    collect_timing: bool = False      # block per round and report round_time_s
    # telemetry sync cadence: with collect_timing the host blocks on the
    # round's metrics only every sync_every rounds (plus the compile
    # round and the last round), so steady-state rounds dispatch
    # back-to-back with ZERO host syncs in between — the device-resident
    # round path.  1 (default) keeps the classic per-round sync; the
    # resilience guard forces per-round syncs regardless (its health
    # verdict is a host read by design).
    sync_every: int = 1
    # pad every cohort to the static capacity C_max = ceil(attendance * N)
    # and thread an attendance mask through the round, so ONE compiled
    # round function serves every live cohort size (no XLA retraces)
    pad_cohorts: bool = True
    # realistic availability: per-round cohort size ~ Binomial(N, attendance)
    # (clipped to [min_cohort, C_max]) instead of the fixed round(a*N)
    variable_attendance: bool = False
    # --- mesh-native execution (replaces the old un-serializable
    # CycleConfig.batch_constraint callable hook) ---
    # device mesh laid over the first prod(mesh_shape) devices, e.g.
    # (8, 1) over ('data', 'model'); None = classic single-device round
    mesh_shape: Optional[tuple] = None
    mesh_axes: tuple = ("data", "model")
    # shard the cohort/data dims over the batch axes (client stack's
    # leading cohort dim, round batches, the pooled feature store, the
    # resampled server minibatches); False = weight placement only
    shard_cohort: bool = True
    # resume from the latest checkpoint under ckpt_dir: Engine.run()
    # restores the TrainState and continues at the saved round, keeping
    # the eval/ckpt cadence and the cohort-sampling stream aligned
    resume: bool = False
    # --- pipelined rounds ---
    # 0 = classic sequential rounds (one monolithic jitted round);
    # L >= 1 = software pipeline over L+1 in-flight cohorts: Extract-
    # Features compiles as its own dispatch and the run loop keeps an
    # L-deep ring of extracted stages, so cohorts k+1..k+L extract
    # against bounded-stale snapshots while cohort k's ServerUpdate/
    # FeatureGradients/Commit tail runs
    pipeline_depth: int = 0
    # 'sync'  — barrier mode: extract(k+1) waits for Commit(k); bit-for-
    #           bit identical to the sequential Engine at ANY depth (the
    #           equivalence goldens in tests/test_pipeline.py pin this;
    #           the ring degenerates to one in-flight stage)
    # 'async' — latency-hiding mode: extract(k+L) is dispatched from the
    #           pre-tail state of round k while ServerUpdate(k) occupies
    #           the model axes; client params and the θ_S^t snapshot are
    #           stale by AT MOST pipeline_depth rounds, never more
    pipeline_staleness: str = "sync"
    # --- staleness-weighted server updates (arxiv 2112.05929-style) ---
    # 'none'    — stale cohorts contribute at full weight (default; the
    #             pipelined tail keeps its exact pre-weighting trace)
    # 'inverse' — scale each cohort's server gradients and feature
    #             gradients by w = 1 / (1 + lag)
    # 'exp'     — scale by w = exp(-staleness_lambda * lag)
    # lag is the cohort's realized snapshot lag in rounds, passed into
    # the compiled tail as a traced scalar (one trace across all lags);
    # w(0) == 1.0 exactly, so sync schedules are a numerical no-op vs
    # 'none' (allclose; the traced multiply may re-fuse reductions).
    staleness_weighting: str = "none"
    staleness_lambda: float = 0.5
    # --- client-population scenario (repro.scenario) ---
    # kind='none' (default) is the NULL scenario: no profile stream is
    # built and the Engine runs its scenario-free path bit-for-bit.
    # Other kinds fold per-round churn into the existing compile-once
    # machinery: profile-weighted cohort sampling, mid-round dropouts
    # zeroing slots in the attendance mask, and straggler lag accounted
    # against the pipeline_staleness snapshot path.
    scenario: ScenarioConfig = field(default_factory=ScenarioConfig)
    # --- fault-tolerant runtime (repro.resilience) ---
    # the default (guard off, no faults) is the NULL config: no guard
    # phase is compiled, no recovery controller is built, and the Engine
    # runs its guard-free path bit-for-bit.  guard=True folds NaN/Inf +
    # loss-spike checks into the compiled round and arms the per-fault
    # recovery policies (quarantine / retry / rollback).
    resilience: ResilienceConfig = field(default_factory=ResilienceConfig)
    # --- continuous-batching serve runtime (repro.serve) ---
    # knobs for the serving-side consumer of this config: slot-table
    # capacity, prompt/generation budgets, deadlines and retry/backoff.
    # Training ignores it; `repro.launch.serve --continuous` and
    # `benchmarks/bench_serving.py` build their runtime from it.
    serve: ServeConfig = field(default_factory=ServeConfig)
    cycle: CycleConfig = field(default_factory=CycleConfig)

    # ---------------------------------------------------------- builders
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentConfig":
        d = dict(d)
        cycle = d.pop("cycle", {})
        if not isinstance(cycle, CycleConfig):
            cycle = dict(cycle)
            # pre-mesh configs serialized the removed batch_constraint
            # hook as null; tolerate the key so old JSONs still load
            cycle.pop("batch_constraint", None)
            cycle = CycleConfig(**cycle)
        # pre-scenario configs simply lack the key -> null scenario
        scenario = d.pop("scenario", {})
        if not isinstance(scenario, ScenarioConfig):
            scenario = ScenarioConfig.from_dict(scenario)
        # pre-resilience configs simply lack the key -> null resilience
        resilience = d.pop("resilience", {})
        if not isinstance(resilience, ResilienceConfig):
            resilience = ResilienceConfig.from_dict(resilience)
        # pre-serve configs simply lack the key -> default serve knobs
        serve = d.pop("serve", {})
        if not isinstance(serve, ServeConfig):
            serve = ServeConfig.from_dict(serve)
        # JSON round-trip turns tuples into lists; normalize back
        if d.get("mesh_shape") is not None:
            d["mesh_shape"] = tuple(int(s) for s in d["mesh_shape"])
        if d.get("mesh_axes") is not None:
            d["mesh_axes"] = tuple(d["mesh_axes"])
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown ExperimentConfig fields: {sorted(unknown)}")
        return cls(cycle=cycle, scenario=scenario, resilience=resilience,
                   serve=serve, **d)

    def validate(self) -> "ExperimentConfig":
        from repro.api.registry import PROGRAMS
        from repro.api.tasks import TASKS
        if self.algo.lower() not in PROGRAMS:
            raise KeyError(f"unknown algorithm {self.algo!r}: "
                           f"{sorted(PROGRAMS)}")
        if self.task not in TASKS:
            raise KeyError(f"unknown task {self.task!r}: {sorted(TASKS)}")
        if self.mesh_shape is not None:
            if len(self.mesh_shape) != len(self.mesh_axes):
                raise ValueError(
                    f"mesh_shape {self.mesh_shape} and mesh_axes "
                    f"{self.mesh_axes} must have equal length")
            if any(int(s) < 1 for s in self.mesh_shape):
                raise ValueError(f"mesh_shape {self.mesh_shape} must be "
                                 "positive")
        if self.sync_every < 1:
            raise ValueError(f"sync_every={self.sync_every}: the host "
                             "must sync at least every round (>= 1)")
        if self.pipeline_depth < 0:
            raise ValueError(
                f"pipeline_depth={self.pipeline_depth}: expected 0 "
                "(sequential) or a positive staleness window L")
        if self.pipeline_staleness not in ("sync", "async"):
            raise ValueError(
                f"pipeline_staleness={self.pipeline_staleness!r}: expected "
                "'sync' or 'async'")
        if self.staleness_weighting not in ("none", "inverse", "exp"):
            raise ValueError(
                f"staleness_weighting={self.staleness_weighting!r}: "
                "expected 'none', 'inverse' or 'exp'")
        if self.staleness_lambda < 0:
            raise ValueError(
                f"staleness_lambda={self.staleness_lambda} must be >= 0")
        self.scenario.validate()
        if self.scenario.churns and not self.pad_cohorts:
            # churn zeroes slots in the attendance mask; without padded
            # cohorts there is no mask to zero (and every distinct live
            # size would retrace anyway)
            raise ValueError(
                f"scenario kind={self.scenario.kind!r} with dropout/"
                "straggler churn requires pad_cohorts=True (mid-round "
                "drops ride the compile-once attendance mask)")
        self.resilience.validate()
        if self.resilience.quarantines and not self.pad_cohorts:
            # quarantine zeroes blamed slots in the attendance mask —
            # same machinery, same requirement as scenario churn
            raise ValueError(
                "resilience quarantine policy requires pad_cohorts=True "
                "(slot quarantine rides the compile-once attendance mask)")
        self.serve.validate()
        return self

    # ------------------------------------------------------------- flags
    @staticmethod
    def add_arguments(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
        from repro.api.registry import algorithm_names
        from repro.api.tasks import task_names
        ap.add_argument("--algo", default="cyclesfl",
                        choices=algorithm_names())
        ap.add_argument("--task", default="image", choices=task_names())
        ap.add_argument("--rounds", type=int, default=100)
        ap.add_argument("--clients", type=int, default=100)
        ap.add_argument("--attendance", type=float, default=0.05)
        ap.add_argument("--batch", type=int, default=16)
        ap.add_argument("--lr-server", type=float, default=1e-3)
        ap.add_argument("--lr-client", type=float, default=1e-3)
        ap.add_argument("--alpha", type=float, default=0.5)
        ap.add_argument("--server-epochs", type=int, default=1)
        ap.add_argument("--server-batch", type=int, default=None)
        ap.add_argument("--grad-clip", type=float, default=None)
        ap.add_argument("--shard-local-resample", action="store_true",
                        help="route the server inner loop's resample "
                             "through the shard_map wrapper (per-shard "
                             "index translation; needs --mesh-shape)")
        ap.add_argument("--resample-kernel", default="auto",
                        choices=("auto", "on", "off"),
                        help="force the Pallas resample kernel on/off "
                             "(auto = kernel on TPU, jnp.take elsewhere)")
        ap.add_argument("--fused-gather-loss", action="store_true",
                        help="fuse the resample gather with the server "
                             "head's loss (linear-head tasks only)")
        ap.add_argument("--seed", type=int, default=0)
        ap.add_argument("--width", type=int, default=16)
        ap.add_argument("--cut", type=int, default=2)
        ap.add_argument("--eval-every", type=int, default=20)
        ap.add_argument("--ckpt-dir", default=None)
        ap.add_argument("--sync-every", type=int, default=1,
                        help="host-sync cadence under --collect-timing: "
                             "block on round metrics every k rounds so "
                             "steady-state rounds stay device-resident")
        ap.add_argument("--no-pad-cohorts", action="store_true",
                        help="disable fixed-shape padded cohorts (forces an "
                             "XLA retrace per distinct cohort size)")
        ap.add_argument("--variable-attendance", action="store_true",
                        help="Binomial(N, attendance) cohort sizes per round")
        ap.add_argument("--mesh-shape", default=None,
                        help="comma-separated mesh shape, e.g. 8,1 — run "
                             "the mesh-native sharded Engine")
        ap.add_argument("--mesh-axes", default="data,model",
                        help="comma-separated mesh axis names")
        ap.add_argument("--no-shard-cohort", action="store_true",
                        help="mesh places weights only; cohort/data dims "
                             "stay replicated")
        ap.add_argument("--resume", action="store_true",
                        help="resume from the latest checkpoint in "
                             "--ckpt-dir")
        ap.add_argument("--pipeline-depth", type=int, default=0,
                        help="L >= 1 keeps an L-deep ring of in-flight "
                             "cohort extractions overlapping the server "
                             "inner loop (0 = sequential)")
        ap.add_argument("--pipeline-staleness", default="sync",
                        choices=("sync", "async"),
                        help="sync = barrier mode (bit-for-bit the "
                             "sequential Engine); async = bounded-stale "
                             "extraction (lag <= depth) overlapped with "
                             "the server phase")
        ap.add_argument("--staleness-weighting", default="none",
                        choices=("none", "inverse", "exp"),
                        help="scale stale cohorts' server/feature "
                             "gradients by realized lag: 1/(1+lag) or "
                             "exp(-lambda*lag)")
        ap.add_argument("--staleness-lambda", type=float, default=0.5,
                        help="decay rate for --staleness-weighting exp")
        ScenarioConfig.add_arguments(ap)
        ResilienceConfig.add_arguments(ap)
        ServeConfig.add_arguments(ap)
        return ap

    @classmethod
    def from_flags(cls, args: argparse.Namespace) -> "ExperimentConfig":
        return cls(
            algo=args.algo, task=args.task, rounds=args.rounds,
            n_clients=args.clients, attendance=args.attendance,
            batch=args.batch, lr_server=args.lr_server,
            lr_client=args.lr_client, alpha=args.alpha, seed=args.seed,
            width=args.width, cut=args.cut, eval_every=args.eval_every,
            ckpt_dir=args.ckpt_dir,
            sync_every=args.sync_every,
            pad_cohorts=not args.no_pad_cohorts,
            variable_attendance=args.variable_attendance,
            mesh_shape=(tuple(int(s) for s in args.mesh_shape.split(","))
                        if args.mesh_shape else None),
            mesh_axes=tuple(args.mesh_axes.split(",")),
            shard_cohort=not args.no_shard_cohort,
            resume=args.resume,
            pipeline_depth=args.pipeline_depth,
            pipeline_staleness=args.pipeline_staleness,
            staleness_weighting=args.staleness_weighting,
            staleness_lambda=args.staleness_lambda,
            scenario=ScenarioConfig.from_flags(args),
            resilience=ResilienceConfig.from_flags(args),
            serve=ServeConfig.from_flags(args),
            cycle=CycleConfig(server_epochs=args.server_epochs,
                              server_batch=args.server_batch,
                              grad_clip=args.grad_clip,
                              shard_local_resample=args.shard_local_resample,
                              resample_use_kernel={"auto": None, "on": True,
                                                   "off": False}[
                                                       args.resample_kernel],
                              fused_gather_loss=args.fused_gather_loss),
        ).validate()

    def with_cycle(self, **kw) -> "ExperimentConfig":
        return replace(self, cycle=replace(self.cycle, **kw))
