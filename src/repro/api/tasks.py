"""Task registry: name -> (SplitTask, FederatedDataset, metric key).

The synthetic stand-ins for the paper's four workloads (§4.1), moved out
of ``launch/train.py`` so every driver (Engine, benchmarks, examples)
builds tasks through one table.  New workloads register with
``register_task`` and are immediately reachable from ``ExperimentConfig``.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.split import SplitTask, make_stage_task
from repro.data.federated import FederatedDataset
from repro.data.synthetic import (SyntheticCharLMTask, SyntheticImageTask,
                                  SyntheticRegressionTask)
from repro.models.cnn import femnist_cnn, mlp, resnet9
from repro.models.lstm import shakespeare_lstm

TaskBuilder = Callable[..., tuple[SplitTask, FederatedDataset, str]]
TASKS: dict[str, TaskBuilder] = {}


def register_task(name: str):
    def deco(fn: TaskBuilder) -> TaskBuilder:
        TASKS[name] = fn
        return fn
    return deco


@register_task("image")
def _image(n_clients, alpha, seed, width, cut):
    gen = SyntheticImageTask(n_clients=n_clients, alpha=alpha, seed=seed)
    x, y, _, idx = gen.build()
    model = femnist_cnn(n_classes=gen.n_classes, width=width)
    task = make_stage_task(model, cut=cut, kind="xent")
    x = x.reshape(len(x), gen.img, gen.img, gen.channels)
    # femnist cnn expects 28x28x1; adapt by padding channels->1 proj
    x = x.mean(axis=-1, keepdims=True)
    x = np.pad(x, ((0, 0), (6, 6), (6, 6), (0, 0)))
    return task, FederatedDataset.from_arrays(x, y, idx, seed=seed), "accuracy"


@register_task("cifar")
def _cifar(n_clients, alpha, seed, width, cut):
    gen = SyntheticImageTask(n_clients=n_clients, alpha=alpha, seed=seed,
                             img=32, n_classes=20, samples_per_client=96)
    x, y, _, idx = gen.build()
    model = resnet9(n_classes=20, width=width)
    task = make_stage_task(model, cut=cut, kind="xent")
    return task, FederatedDataset.from_arrays(x, y, idx, seed=seed), "accuracy"


@register_task("charlm")
def _charlm(n_clients, alpha, seed, width, cut):
    gen = SyntheticCharLMTask(n_clients=n_clients, seed=seed)
    x, y, _, idx = gen.build()
    model = shakespeare_lstm(vocab=gen.vocab)
    task = make_stage_task(model, cut=2, kind="xent")
    return task, FederatedDataset.from_arrays(x, y, idx, seed=seed), "accuracy"


@register_task("gaze")
def _gaze(n_clients, alpha, seed, width, cut):
    gen = SyntheticRegressionTask(n_clients=n_clients, seed=seed)
    x, y, _, idx = gen.build()
    model = mlp(gen.d_in, [128, 64], gen.d_out)
    task = make_stage_task(model, cut=1, kind="mse")
    return task, FederatedDataset.from_arrays(x, y, idx, seed=seed), "angular_deg"


def build_task(name: str, n_clients: int, alpha: float, seed: int,
               width: int, cut: int):
    if name not in TASKS:
        raise KeyError(f"unknown task {name!r}: {sorted(TASKS)}")
    return TASKS[name](n_clients, alpha, seed, width, cut)


def task_names() -> tuple[str, ...]:
    return tuple(sorted(TASKS))
