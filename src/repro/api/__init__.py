"""repro.api — the experiment/engine API.

Three layers, one registry:

* :mod:`repro.api.phases` — ``RoundProgram``: algorithms as declarative
  compositions of typed phases over one ``TrainState`` pytree.
* :mod:`repro.api.registry` / :mod:`repro.api.tasks` — name -> program
  and name -> task tables every driver resolves through.
* :mod:`repro.api.config` / :mod:`repro.api.engine` — frozen
  ``ExperimentConfig`` + the single ``Engine.run()`` driver loop.
"""
from repro.api.config import ExperimentConfig
from repro.api.engine import Engine, evaluate
from repro.api.phases import (ClientUpdate, Commit, ExtractFeatures,
                              FeatureGradients, Phase, PhaseContext,
                              PipelinedAlgorithm, PipelineStage,
                              RoundProgram, RoundVars, ServerUpdate,
                              SLAlgorithm, TrainState, build_algorithm,
                              build_pipelined_algorithm, init_train_state,
                              split_program)
from repro.api.registry import (PROGRAMS, algorithm_names, get_program,
                                register_program)
from repro.api.tasks import TASKS, build_task, register_task, task_names

__all__ = [
    "ExperimentConfig", "Engine", "evaluate",
    "Phase", "PhaseContext", "RoundProgram", "RoundVars", "TrainState",
    "SLAlgorithm", "PipelinedAlgorithm", "PipelineStage",
    "ExtractFeatures", "ServerUpdate", "FeatureGradients",
    "ClientUpdate", "Commit", "build_algorithm",
    "build_pipelined_algorithm", "split_program", "init_train_state",
    "PROGRAMS", "algorithm_names", "get_program", "register_program",
    "TASKS", "build_task", "register_task", "task_names",
]
