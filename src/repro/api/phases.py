"""Declarative round programs: every SL algorithm as a composition of
typed phases over one :class:`TrainState` pytree.

The paper's claim that CycleSL "can be seamlessly integrated with
existing methods" (§3) is made literal here: an algorithm is a
:class:`RoundProgram` — an ordered tuple of phases drawn from

    ExtractFeatures -> ServerUpdate -> FeatureGradients -> ClientUpdate
    -> Commit

so ``cyclepsl``/``cyclesfl``/``cyclesglr`` are exactly ``psl``/``sflv1``/
``sglr`` with ``ServerUpdate(mode=...)`` swapped to the CycleSL inner
loop and ``FeatureGradients`` pointed at the *updated* server (the
cyclical/BCD part, Eq. 5).  The inherently sequential algorithms
(``ssl``, ``sflv2``, ``fedavg``) keep their chained semantics as single
fused phases behind the same interface.

All phases transform a :class:`RoundVars` scratch record inside ONE jit
trace; :func:`build_algorithm` compiles a program into the
``(init, round)`` pair the drivers and the legacy
``repro.core.algorithms.make_algorithm`` shim consume.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.cyclesl import (CycleConfig, client_update_one,
                                client_updates, feature_gradients,
                                server_inner_loop)
from repro.core.feature_store import pool_store
from repro.core.protocol import (EntityState, broadcast_entity, entity_mean,
                                 entity_step, init_entity, masked_axis0_mean,
                                 masked_entity_mean, put_entities,
                                 select_entities, take_entities)
from repro.core.split import SplitTask
from repro.optim import Optimizer
from repro.resilience.guards import health_vector
from repro.sharding.specs import (constrain_cohort, constrain_cohort_tree,
                                  constrain_entity_params, slot_shard_map)


class TrainState(NamedTuple):
    """The single pytree every phase transforms (and checkpoints save).

    ``clients`` is the stacked [N, ...] persistent per-client store
    (PSL-family); ``client_global`` the one shared θ_C (SFL-family).
    Exactly one of the two is populated.
    """
    server: EntityState
    clients: Optional[EntityState]
    client_global: Optional[EntityState]


@dataclass(frozen=True)
class SLAlgorithm:
    """Compiled algorithm: what the drivers actually call.

    ``round`` accepts an optional trailing attendance ``mask`` ([C]
    float, 1.0 = live slot); without it the classic unpadded semantics
    apply.  ``trace_count`` exposes how many times the round function
    has been (re)traced by XLA — the compile-stability contract is ONE
    trace per (algo, config) for the whole experiment.
    """
    name: str
    init: Callable[..., TrainState]
    round: Callable[..., tuple[TrainState, dict]]
    uses_global_client: bool
    traces: Any = None

    @property
    def trace_count(self) -> int:
        return self.traces["count"] if self.traces else 0


@dataclass(frozen=True)
class PhaseContext:
    """Static (trace-time) inputs shared by every phase of a round.

    ``mesh`` (a ``jax.sharding.Mesh`` or ``None``) turns on the mesh-
    native execution path: phases thread ``with_sharding_constraint``
    through cohort-stacked activations (leading cohort dim over the
    batch axes), the pooled feature dataset (rows over 'data'), and the
    resampled server minibatches.  Constraints pin layout only, never
    values — the 1-device-mesh round is bit-for-bit the unsharded one.
    """
    task: SplitTask
    opt_server: Optimizer
    opt_client: Optimizer
    cycle: CycleConfig
    mesh: Any = None


@dataclass
class RoundVars:
    """Mutable scratch flowing phase-to-phase inside one jit trace.

    ``mask`` is the attendance mask over cohort SLOTS ([C] float, 1.0 =
    live client, 0.0 = padded slot), or ``None`` on the classic unpadded
    path.  Padded slots carry the out-of-range sentinel id N in
    ``cohort`` and zeroed ``xs``/``ys``; every phase excludes them from
    pooled/averaged quantities so the padded round is numerically
    identical to an unpadded round over the live slots alone.

    Scenario churn reuses the same contract with one difference: a
    mid-round dropout zeroes a LIVE slot's mask entry (the slot keeps
    its real client id and data).  The zero mask alone is sufficient —
    the slot's pooled rows are invalid before ServerUpdate consumes
    them, its feature gradients are excluded from masked means, and the
    Commit scatter/aggregate weighting drops its contribution — so
    churn needs no new phase logic and no retrace.
    """
    state: TrainState
    cohort: Any                       # [C] int client ids
    xs: Any                           # [C, b, ...] inputs
    ys: Any                           # [C, b, ...] labels
    key: Any
    mask: Any = None                  # [C] attendance mask (None = unpadded)
    ema: Any = None                   # loss-EMA carry (guard-on rounds only)
    cohort_clients: Optional[EntityState] = None
    server_prev: Any = None           # θ_S^t params, pre-ServerUpdate
    feats: Any = None                 # [C, b, ...] smashed data
    store: Any = None                 # prebuilt pooled D_S^f (pipelined
                                      # extract handoff); None = pool inline
    fgrads: Any = None                # [C, b, ...] feature gradients
    stale_w: Any = None               # traced staleness weight w(lag)
                                      # (None = unweighted; w scales the
                                      # server + feature gradients)
    metrics: dict = field(default_factory=dict)


class Phase:
    """A typed round phase: ``(PhaseContext, RoundVars) -> None``."""

    def __call__(self, ctx: PhaseContext, v: RoundVars) -> None:
        raise NotImplementedError


def masked_mean(x, mask):
    """Mean over the live cohort slots (all slots when ``mask`` is None).
    With an all-ones mask this is bit-identical to ``jnp.mean``.  The
    denominator is floored at 1 so an all-dropped mask (every live slot
    zeroed by scenario churn — the Engine's min_live revival makes this
    unreachable in practice) yields 0, not NaN; with >= 1 live slot the
    floor is inert and the result is bit-identical to the plain ratio."""
    if mask is None:
        return jnp.mean(x)
    return (jnp.sum(jnp.where(mask > 0, x, 0))
            / jnp.maximum(jnp.sum(mask), 1.0))


def feat_grad_metrics(fgrads, mask=None) -> dict:
    fg = fgrads.reshape(fgrads.shape[0], -1).astype(jnp.float32)
    norms = jnp.linalg.norm(fg, axis=-1) / jnp.sqrt(fg.shape[-1])
    if mask is None:
        return {"feat_grad_norm_mean": jnp.mean(norms),
                "feat_grad_norm_std": jnp.std(norms)}
    mu = masked_mean(norms, mask)
    var = masked_mean(jnp.square(jnp.abs(norms - mu)), mask)
    return {"feat_grad_norm_mean": mu,
            "feat_grad_norm_std": jnp.sqrt(var)}


# ----------------------------------------------------------------- phases
@dataclass(frozen=True)
class ExtractFeatures(Phase):
    """Phase 1: select the cohort's client models and extract smashed
    data in parallel.  Also snapshots θ_S^t so later phases can choose
    the pre-update server (non-cycle algorithms)."""

    def __call__(self, ctx, v):
        state = v.state
        v.cohort_clients = (
            broadcast_entity(state.client_global, v.ys.shape[0])
            if state.clients is None
            else take_entities(state.clients, v.cohort))
        if ctx.mesh is not None:
            # cohort-parallel extraction: the [C, ...] client stack and
            # its smashed data live sharded over the batch axes
            v.cohort_clients = constrain_cohort_tree(v.cohort_clients,
                                                     ctx.mesh)
        v.server_prev = state.server.params
        # slot-parallel extraction runs INSIDE a shard_map: GSPMD
        # replicates the cohort-vmapped grouped convs (every device
        # computes all C slots, then slices its own), which is the bulk
        # of the 1->8 device weak-scaling loss (§Weak scaling)
        v.feats = slot_shard_map(
            jax.vmap(ctx.task.client_forward), ctx.mesh,
            (v.cohort_clients.params, v.xs))
        v.feats = constrain_cohort(v.feats, ctx.mesh)


def _pair_server_losses_and_grads(ctx, v):
    """Per-pair server loss/grad at θ_S^t over the cohort's features."""

    def one(f, y, sp):
        return jax.value_and_grad(ctx.task.server_loss)(sp, f, y)

    return slot_shard_map(jax.vmap(one, in_axes=(0, 0, None)), ctx.mesh,
                          (v.feats, v.ys), (v.state.server.params,))


@dataclass(frozen=True)
class ServerUpdate(Phase):
    """Phase 2, the axis the zoo varies along:

    ``cycle``        pool features into D_S^f and run the CycleSL inner
                     loop (E epochs of resampled minibatches, Eq. 3) —
                     the paper's standalone higher-level server task.
    ``replica_avg``  PSL/SFL-V1: per-pair server replica steps, then
                     replica (model) averaging.
    ``mean_grad``    SGLR: one server stepped with the cohort-mean
                     gradient (no model duplication).
    """
    mode: str = "cycle"

    def __call__(self, ctx, v):
        if self.mode == "cycle":
            # the pooled feature dataset D_S^f stays sharded over the
            # batch axes; the masked resample inside the inner loop is a
            # sharded permutation-gather (feature_resample kernel on TPU;
            # ctx.cycle.shard_local_resample routes it through the
            # shard_map wrapper so the gather stays shard-LOCAL, and
            # ctx.cycle.fused_gather_loss fuses it with the head loss —
            # both knobs ride CycleConfig, so the monolithic round and
            # the pipelined tail take the same path).  A pipelined
            # extract dispatch hands the finished pool over via v.store;
            # both paths build it with the same pool_store.
            store = (v.store if v.store is not None
                     else pool_store(v.feats, v.ys, mask=v.mask,
                                     mesh=ctx.mesh))
            server, sloss = server_inner_loop(
                ctx.task, v.state.server, ctx.opt_server, store, v.key,
                ctx.cycle, batch=jax.tree.leaves(v.ys)[0].shape[1],
                mesh=ctx.mesh, grad_scale=v.stale_w)
            v.metrics["server_loss"] = sloss
        elif self.mode == "replica_avg":
            losses, gs = _pair_server_losses_and_grads(ctx, v)
            if v.stale_w is not None:
                gs = jax.tree.map(lambda g: g * v.stale_w, gs)
            rep = broadcast_entity(v.state.server, v.ys.shape[0])
            if ctx.mesh is not None:
                rep = constrain_cohort_tree(rep, ctx.mesh)
                gs = constrain_cohort_tree(gs, ctx.mesh)
            rep = slot_shard_map(
                jax.vmap(lambda e, g: entity_step(e, g, ctx.opt_server)),
                ctx.mesh, (rep, gs))
            server = (entity_mean(rep) if v.mask is None
                      else masked_entity_mean(rep, v.mask))
            v.metrics["server_loss"] = masked_mean(losses, v.mask)
        elif self.mode == "mean_grad":
            losses, gs = _pair_server_losses_and_grads(ctx, v)
            if v.mask is None:
                gmean = jax.tree.map(lambda g: jnp.mean(g, axis=0), gs)
            else:
                gmean = jax.tree.map(
                    lambda g: masked_axis0_mean(g, v.mask), gs)
            if v.stale_w is not None:
                gmean = jax.tree.map(lambda g: g * v.stale_w, gmean)
            server = entity_step(v.state.server, gmean, ctx.opt_server)
            v.metrics["server_loss"] = masked_mean(losses, v.mask)
        else:
            raise ValueError(f"unknown ServerUpdate mode {self.mode!r}")
        v.state = v.state._replace(server=server)


@dataclass(frozen=True)
class FeatureGradients(Phase):
    """Phase 3: B_i^g = ∇_{B_i^f} L(θ_S(B_i^f)) with θ_S frozen.

    ``use_updated=True`` reads θ_S^{t+1} (the cyclical part, Eq. 5);
    ``False`` reads the θ_S^t snapshot (classic SL back-prop order).
    ``average`` forces SGLR-style cohort-mean gradients on (True) or
    off (False); ``None`` defers to ``CycleConfig.avg_client_grads``.
    """
    use_updated: bool = True
    average: Optional[bool] = None

    def __call__(self, ctx, v):
        params = (v.state.server.params if self.use_updated
                  else v.server_prev)
        avg = (ctx.cycle.avg_client_grads if self.average is None
               else self.average)
        ccfg = (ctx.cycle if avg == ctx.cycle.avg_client_grads
                else replace(ctx.cycle, avg_client_grads=avg))
        fg = feature_gradients(ctx.task, params, v.feats, v.ys, ccfg,
                               mask=v.mask, mesh=ctx.mesh)
        if v.stale_w is not None:
            fg = fg * v.stale_w.astype(fg.dtype)
        v.fgrads = constrain_cohort(fg, ctx.mesh)
        v.metrics.update(feat_grad_metrics(v.fgrads, mask=v.mask))


@dataclass(frozen=True)
class ClientUpdate(Phase):
    """Phase 4: pull feature gradients through each client's local VJP.

    ``chained=True`` runs the sequential-SL variant: ONE client model
    scanned along the cohort (each update sees the previous one), used
    by ``cyclessl``.  Both paths share ``client_update_one`` and respect
    ``CycleConfig.grad_clip``.
    """
    record_gnorm: bool = False
    chained: bool = False

    def __call__(self, ctx, v):
        clip = ctx.cycle.grad_clip
        if self.chained:
            if v.mask is None:
                def body(entity, inp):
                    x, g = inp
                    return client_update_one(ctx.task, entity, x, g,
                                             ctx.opt_client, clip)
                v.cohort_clients, gnorms = jax.lax.scan(
                    body, v.state.client_global, (v.xs, v.fgrads))
            else:
                # padded slots pass the chained carry through unchanged
                def body(entity, inp):
                    x, g, m = inp
                    new, gn = client_update_one(ctx.task, entity, x, g,
                                                ctx.opt_client, clip)
                    return (select_entities(m, new, entity),
                            jnp.where(m > 0, gn, 0.0))
                v.cohort_clients, gnorms = jax.lax.scan(
                    body, v.state.client_global, (v.xs, v.fgrads, v.mask))
        else:
            v.cohort_clients, gnorms = client_updates(
                ctx.task, v.cohort_clients, ctx.opt_client, v.xs, v.fgrads,
                grad_clip=clip, mask=v.mask, mesh=ctx.mesh)
            if ctx.mesh is not None:
                # sharded VJPs: updated cohort entities stay cohort-sharded
                # into the commit scatter/average
                v.cohort_clients = constrain_cohort_tree(v.cohort_clients,
                                                         ctx.mesh)
        if self.record_gnorm:
            v.metrics["client_grad_norm_mean"] = masked_mean(gnorms, v.mask)


@dataclass(frozen=True)
class Commit(Phase):
    """Phase 5: write the updated cohort back into the train state.

    ``per_client``  scatter into the persistent [N, ...] client store
                    (PSL-family: clients are never aggregated).
    ``average``     FedAvg the cohort into the shared θ_C (SFL-family).
    ``global``      replace the shared θ_C wholesale (sequential chain).
    """
    mode: str = "per_client"

    def __call__(self, ctx, v):
        state, cc = v.state, v.cohort_clients
        if self.mode == "per_client":
            # padded slots carry the OOB sentinel id; put_entities'
            # mode="drop" scatter discards their (already zeroed) updates
            v.state = state._replace(
                clients=put_entities(state.clients, v.cohort, cc))
        elif self.mode == "average":
            v.state = state._replace(
                client_global=(entity_mean(cc) if v.mask is None
                               else masked_entity_mean(cc, v.mask)))
        elif self.mode == "global":
            v.state = state._replace(client_global=cc)
        else:
            raise ValueError(f"unknown Commit mode {self.mode!r}")


@dataclass(frozen=True)
class HealthGuard(Phase):
    """Trailing phase: fold the health verdict into the round's metrics.

    Appended by the builders only when ``ResilienceConfig.guard`` is on,
    so the guard-free program compiles to the identical HLO it always
    did (bit-for-bit when disabled).  Everything it reads — the committed
    state, the round loss, the cohort intermediates, the loss-EMA carry
    (``v.ema``, a device scalar the Engine threads round-to-round) — is
    already live inside the trace, so the check costs no extra dispatch;
    the Engine pays exactly one host sync reading ``metrics['health']``.
    See :mod:`repro.resilience.guards` for the vector layout.
    """
    alpha: float = 0.1
    spike_factor: float = 4.0

    def __call__(self, ctx, v):
        loss = v.metrics.get("server_loss", jnp.zeros(()))
        health, slot_bad = health_vector(
            v.state, loss, v.feats, v.fgrads, v.mask, v.ema,
            self.alpha, self.spike_factor)
        v.metrics["health"] = health
        v.metrics["health_slot_bad"] = slot_bad


# ----------------------------------------------- fused sequential rounds
# ssl / sflv2 / fedavg interleave client and server updates inside one
# scan, so they cannot be expressed as the 5-phase pipeline without
# changing semantics; they ride as single fused phases instead.
@dataclass(frozen=True)
class SequentialChainRound(Phase):
    """ssl: one shared client model passed client-to-client, end-to-end
    update per client (the O(N)-latency canon)."""

    def __call__(self, ctx, v):
        task, opt_s, opt_c = ctx.task, ctx.opt_server, ctx.opt_client
        masked = v.mask is not None

        def body(carry, inp):
            server, client = carry
            x, y = inp[:2]

            def loss_fn(c, s):
                return task.e2e_loss(c, s, x, y)
            loss, (gc, gs) = jax.value_and_grad(loss_fn, (0, 1))(
                client.params, server.params)
            f = task.client_forward(client.params, x)
            fg = jax.grad(lambda ff: task.server_loss(
                jax.lax.stop_gradient(server.params), ff, y))(f)
            new_s = entity_step(server, gs, opt_s)
            new_c = entity_step(client, gc, opt_c)
            if masked:
                m = inp[2]
                new_s = select_entities(m, new_s, server)
                new_c = select_entities(m, new_c, client)
                loss = jnp.where(m > 0, loss, 0.0)
            return (new_s, new_c), (loss, fg)

        inputs = (v.xs, v.ys, v.mask) if masked else (v.xs, v.ys)
        (server, client), (losses, fg) = jax.lax.scan(
            body, (v.state.server, v.state.client_global), inputs)
        v.metrics.update(server_loss=masked_mean(losses, v.mask),
                         **feat_grad_metrics(fg, mask=v.mask))
        v.state = v.state._replace(server=server, client_global=client)


@dataclass(frozen=True)
class ServerSequentialRound(Phase):
    """sflv2: single server model, clients processed sequentially on the
    server side; client models FedAvg'd at round end."""

    def __call__(self, ctx, v):
        task, opt_s, opt_c = ctx.task, ctx.opt_server, ctx.opt_client
        masked = v.mask is not None
        cohort_clients = broadcast_entity(v.state.client_global,
                                          v.ys.shape[0])

        def body(server, inp):
            cp, x, y = inp[:3]

            def loss_fn(c, s):
                return task.e2e_loss(c, s, x, y)
            loss, (gc, gs) = jax.value_and_grad(loss_fn, (0, 1))(
                cp, server.params)
            f = task.client_forward(cp, x)
            fg = jax.grad(lambda ff: task.server_loss(
                jax.lax.stop_gradient(server.params), ff, y))(f)
            new_s = entity_step(server, gs, opt_s)
            if masked:
                m = inp[3]
                new_s = select_entities(m, new_s, server)
                loss = jnp.where(m > 0, loss, 0.0)
            return new_s, (loss, gc, fg)

        inputs = ((cohort_clients.params, v.xs, v.ys, v.mask) if masked
                  else (cohort_clients.params, v.xs, v.ys))
        server, (losses, gc, fg) = jax.lax.scan(
            body, v.state.server, inputs)
        stepped = jax.vmap(
            lambda e, g: entity_step(e, g, ctx.opt_client))(cohort_clients, gc)
        client_global = (entity_mean(stepped) if not masked
                         else masked_entity_mean(stepped, v.mask))
        v.metrics.update(server_loss=masked_mean(losses, v.mask),
                         **feat_grad_metrics(fg, mask=v.mask))
        v.state = v.state._replace(server=server,
                                   client_global=client_global)


@dataclass(frozen=True)
class LocalFedAvgRound(Phase):
    """fedavg: clients train the FULL composed model locally; both halves
    are averaged (no split traffic — the non-SL yardstick)."""

    def __call__(self, ctx, v):
        task, opt_s, opt_c = ctx.task, ctx.opt_server, ctx.opt_client
        n = v.ys.shape[0]
        servers = broadcast_entity(v.state.server, n)
        clients = broadcast_entity(v.state.client_global, n)
        if ctx.mesh is not None:
            servers = constrain_cohort_tree(servers, ctx.mesh)
            clients = constrain_cohort_tree(clients, ctx.mesh)

        def one(se, ce, x, y):
            def loss_fn(c, s):
                return task.e2e_loss(c, s, x, y)
            loss, (gc, gs) = jax.value_and_grad(loss_fn, (0, 1))(
                ce.params, se.params)
            return (entity_step(se, gs, opt_s),
                    entity_step(ce, gc, opt_c), loss)

        new_servers, new_clients, losses = slot_shard_map(
            jax.vmap(one), ctx.mesh, (servers, clients, v.xs, v.ys))
        if v.mask is None:
            server, client = entity_mean(new_servers), entity_mean(new_clients)
        else:
            server = masked_entity_mean(new_servers, v.mask)
            client = masked_entity_mean(new_clients, v.mask)
        v.metrics.update(server_loss=masked_mean(losses, v.mask),
                         feat_grad_norm_mean=jnp.zeros(()),
                         feat_grad_norm_std=jnp.zeros(()))
        v.state = v.state._replace(server=server, client_global=client)


# ---------------------------------------------------------------- program
@dataclass(frozen=True)
class RoundProgram:
    """A named, declarative composition of phases = one SL algorithm."""
    name: str
    phases: tuple[Phase, ...]
    uses_global_client: bool

    def describe(self) -> str:
        return " -> ".join(type(p).__name__ for p in self.phases)


def init_train_state(key, n_clients: int, task: SplitTask,
                     opt_server: Optimizer, opt_client: Optimizer,
                     global_client: bool) -> TrainState:
    ks, kc = jax.random.split(key)
    server = init_entity(task.init_server(ks), opt_server)
    client0 = init_entity(task.init_client(kc), opt_client)
    if global_client:
        return TrainState(server, None, client0)
    # per-client persistent models — identical init (the paper initializes
    # every client the same way; heterogeneity comes from the data)
    return TrainState(server, broadcast_entity(client0, n_clients), None)


def build_algorithm(program: RoundProgram, task: SplitTask,
                    opt_server: Optimizer, opt_client: Optimizer,
                    cycle: CycleConfig = CycleConfig(),
                    donate: bool = False,
                    mesh: Any = None,
                    state_shardings: Any = None,
                    shard_data: bool = True,
                    resilience: Any = None) -> SLAlgorithm:
    """Compile a RoundProgram into the uniform algorithm interface.

    ``donate=True`` donates the TrainState buffers to the jitted round
    (in-place on accelerators; skipped by the Engine on CPU where XLA
    cannot honor donation).

    ``resilience`` (a :class:`~repro.resilience.ResilienceConfig` with
    ``guard=True``) appends the :class:`HealthGuard` phase and the round
    gains a trailing ``ema`` carry argument; ``None``/guard-off compiles
    the exact guard-free round (the ``ema=None`` default never enters
    the trace when the caller omits it).

    ``mesh`` + ``state_shardings`` switch on the mesh-native path:
    phases thread ``with_sharding_constraint`` (cohort activations and
    the pooled feature store over the batch axes, server minibatches
    data-parallel), and the jitted round pins its output TrainState to
    ``state_shardings`` — so round N+1's input sharding equals round N's
    output sharding and the compile-once contract holds per
    (algo, config, mesh).  ``shard_data=False`` keeps the weight
    placement but drops the cohort/data constraints
    (``ExperimentConfig.shard_cohort``).
    """
    ctx = PhaseContext(task, opt_server, opt_client, cycle,
                       mesh if shard_data else None)
    traces = {"count": 0}
    guard = (HealthGuard(resilience.ema_alpha, resilience.spike_factor)
             if resilience is not None and resilience.guard else None)

    def init(key, n_clients: int) -> TrainState:
        return init_train_state(key, n_clients, task, opt_server, opt_client,
                                program.uses_global_client)

    def round_impl(state, cohort, xs, ys, key, mask=None, ema=None):
        traces["count"] += 1          # executes at trace time only
        v = RoundVars(state=state, cohort=cohort, xs=xs, ys=ys, key=key,
                      mask=mask, ema=ema)
        for phase in program.phases:
            phase(ctx, v)
        if guard is not None:
            guard(ctx, v)
        return v.state, v.metrics

    jit_kwargs = {}
    if donate:
        jit_kwargs["donate_argnums"] = (0,)
    if state_shardings is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        out_mesh = jax.tree.leaves(state_shardings)[0].mesh
        # metrics are scalars -> replicated; the state sharding pin is
        # what keeps round-over-round input shardings (and therefore the
        # trace count) stable
        jit_kwargs["out_shardings"] = (
            state_shardings, NamedSharding(out_mesh, PartitionSpec()))
    round_fn = jax.jit(round_impl, **jit_kwargs)
    return SLAlgorithm(program.name, init, round_fn,
                       program.uses_global_client, traces)


# ------------------------------------------------------ pipelined rounds
class PipelineStage(NamedTuple):
    """Everything the Extract dispatch hands to the in-flight tail.

    One stage per in-flight cohort: the selected client entities, the
    θ_S^t snapshot (read by non-cycle ``FeatureGradients``), the smashed
    data, and — for cycle programs — the already-pooled D_S^f so the
    tail's server phase starts on the handoff without re-pooling.

    ``clients`` is the [C, ...] gathered stack for per-client programs,
    but the SINGLE shared θ_C entity for global-client programs: the
    tail re-broadcasts it so the broadcast stays logical inside the
    trace — materializing C identical copies at the dispatch boundary
    perturbs conv-VJP bits, and the snapshot-in-stage semantics (async
    staleness rides the stage, never the tail's state) are unchanged.
    """
    clients: Any                      # [C, ...] stack, or shared θ_C entity
    server_prev: Any                  # θ_S^t params snapshot
    feats: Any                        # [C, b, ...] smashed data; None for
    #                                   cycle programs (the pooled store
    #                                   carries the same values — the tail
    #                                   rebuilds this view by reshape, so
    #                                   the boundary moves the cohort's
    #                                   features ONCE, not twice)
    store: Any                        # pooled FeatureStore (cycle) or None


@dataclass(frozen=True)
class PipelinedAlgorithm:
    """A RoundProgram compiled as TWO overlappable dispatches.

    ``extract(state, cohort, xs, ys[, mask]) -> PipelineStage`` runs the
    ExtractFeatures head on the cohort batch axes; ``tail(state, cohort,
    xs, ys, key, stage[, mask]) -> (state, metrics)`` runs the
    ServerUpdate/FeatureGradients/ClientUpdate/Commit remainder.  Their
    composition with a barrier is the sequential round; dispatching
    ``extract`` for cohort k+1 before ``tail`` of cohort k is the
    software pipeline.  ``traces`` tracks both functions — the compile
    contract is ONE trace each per (algo, config, mesh).
    """
    name: str
    init: Callable[..., TrainState]
    extract: Callable[..., PipelineStage]
    tail: Callable[..., tuple[TrainState, dict]]
    uses_global_client: bool
    traces: Any = None

    @property
    def extract_traces(self) -> int:
        return self.traces["extract"] if self.traces else 0

    @property
    def tail_traces(self) -> int:
        return self.traces["tail"] if self.traces else 0

    @property
    def trace_count(self) -> int:
        return self.extract_traces + self.tail_traces


def split_program(program: RoundProgram
                  ) -> Optional[tuple[Phase, tuple[Phase, ...]]]:
    """(head, tail) when the program starts with ExtractFeatures; None
    for the fused sequential programs (ssl/sflv2/fedavg interleave
    client and server updates inside one scan — there is nothing to
    overlap, and the Engine falls back to the monolithic round)."""
    if program.phases and isinstance(program.phases[0], ExtractFeatures):
        return program.phases[0], program.phases[1:]
    return None


def build_pipelined_algorithm(program: RoundProgram, task: SplitTask,
                              opt_server: Optimizer, opt_client: Optimizer,
                              cycle: CycleConfig = CycleConfig(),
                              donate: bool = False,
                              donate_state: bool = True,
                              mesh: Any = None,
                              state_shardings: Any = None,
                              shard_data: bool = True,
                              resilience: Any = None,
                              staleness_weighting: str = "none",
                              staleness_lambda: float = 0.5,
                              pin_stage: bool = False
                              ) -> Optional[PipelinedAlgorithm]:
    """Compile a RoundProgram into the (extract, tail) dispatch pair.

    The phases are the SAME objects the monolithic round runs — the
    split only moves the jit boundary to the ExtractFeatures/ServerUpdate
    seam (plus the D_S^f pooling, which rides the extract side via
    ``pool_store``), so ``tail(state, ..., extract(state, ...))`` is
    numerically the monolithic ``round``.  Returns None when the program
    has no ExtractFeatures head to split on.

    ``donate=True`` donates the stage buffers into the tail (they die
    with the round); ``donate_state`` additionally donates the TrainState
    — the Engine switches it off in async mode, where the pre-tail state
    is still in flight inside the next cohort's extract dispatch.

    ``staleness_weighting`` != 'none' gives the tail an extra traced
    ``lag`` scalar and scales the cohort's server + feature gradients by
    w(lag) (``1/(1+lag)`` or ``exp(-staleness_lambda*lag)``) — one tail
    trace across every realized lag, and 'none' keeps the exact
    pre-weighting signature so depth-1 goldens stay bit-for-bit.
    ``pin_stage`` (deep rings, L > 1) runs the extracted stage through
    :func:`repro.sharding.specs.constrain_stage` so every buffered
    stage holds one stable placement regardless of how many are in
    flight; off by default to leave the depth-1 lowering untouched.
    """
    split = split_program(program)
    if split is None:
        return None
    head, tail_phases = split
    ctx = PhaseContext(task, opt_server, opt_client, cycle,
                       mesh if shard_data else None)
    pools = any(getattr(p, "mode", None) == "cycle" for p in tail_phases)
    traces = {"extract": 0, "tail": 0}
    guard = (HealthGuard(resilience.ema_alpha, resilience.spike_factor)
             if resilience is not None and resilience.guard else None)

    def init(key, n_clients: int) -> TrainState:
        return init_train_state(key, n_clients, task, opt_server, opt_client,
                                program.uses_global_client)

    def extract_impl(state, cohort, xs, ys, mask=None):
        traces["extract"] += 1        # executes at trace time only
        v = RoundVars(state=state, cohort=cohort, xs=xs, ys=ys, key=None,
                      mask=mask)
        head(ctx, v)
        store = (pool_store(v.feats, ys, mask=mask, mesh=ctx.mesh)
                 if pools else None)
        # cycle programs: the pooled store IS the smashed data (a
        # stop_gradient + reshape of it), so handing both across the
        # dispatch boundary would materialize the cohort's features
        # twice; the tail rebuilds the [C, b, ...] view by the inverse
        # reshape (bit-identical values — FeatureGradients reads feats
        # as a point, never through its graph)
        feats = None if pools else v.feats
        # θ_S^t keeps its FSDP/TP weight placement while the cohort
        # tensors sit on the batch axes — the disjoint-axis layout that
        # lets XLA overlap this dispatch with the server inner loop
        server_prev = constrain_entity_params(v.server_prev, ctx.mesh)
        # global-client programs hand over the un-broadcast θ_C snapshot
        # (see PipelineStage); per-client programs the gathered stack
        clients = (state.client_global if program.uses_global_client
                   else v.cohort_clients)
        stage = PipelineStage(clients, server_prev, feats, store)
        if pin_stage and ctx.mesh is not None:
            from repro.sharding.specs import constrain_stage
            stage = constrain_stage(stage, ctx.mesh,
                                    program.uses_global_client)
        return stage

    def tail_impl(state, cohort, xs, ys, key, stage, mask=None, ema=None,
                  lag=None):
        traces["tail"] += 1           # executes at trace time only
        stale_w = None
        if staleness_weighting != "none":
            l = jnp.asarray(0.0 if lag is None else lag, jnp.float32)
            stale_w = (1.0 / (1.0 + l) if staleness_weighting == "inverse"
                       else jnp.exp(-staleness_lambda * l))
        cohort_clients = stage.clients
        if program.uses_global_client:
            # re-broadcast the snapshot INSIDE the trace so XLA keeps it
            # logical — bit-identical to the monolithic round's lowering
            cohort_clients = broadcast_entity(stage.clients,
                                              jax.tree.leaves(ys)[0].shape[0])
            if ctx.mesh is not None:
                cohort_clients = constrain_cohort_tree(cohort_clients,
                                                       ctx.mesh)
        feats = stage.feats
        if feats is None:              # rebuild the [C, b, ...] view
            pooled = stage.store.features
            cb = jax.tree.leaves(ys)[0].shape[:2]
            feats = pooled.reshape(cb + pooled.shape[1:])
        v = RoundVars(state=state, cohort=cohort, xs=xs, ys=ys, key=key,
                      mask=mask, ema=ema, cohort_clients=cohort_clients,
                      server_prev=stage.server_prev, feats=feats,
                      store=stage.store, stale_w=stale_w)
        for phase in tail_phases:
            phase(ctx, v)
        if guard is not None:
            guard(ctx, v)
        if stale_w is not None:
            v.metrics["stale_weight"] = stale_w
        return v.state, v.metrics

    tail_kwargs = {}
    if donate:
        # the stage dies with the round it feeds; the state is donated
        # only when the caller guarantees no other dispatch still reads it
        tail_kwargs["donate_argnums"] = ((0, 5) if donate_state else (5,))
    if state_shardings is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        out_mesh = jax.tree.leaves(state_shardings)[0].mesh
        tail_kwargs["out_shardings"] = (
            state_shardings, NamedSharding(out_mesh, PartitionSpec()))
    return PipelinedAlgorithm(program.name, init,
                              jax.jit(extract_impl),
                              jax.jit(tail_impl, **tail_kwargs),
                              program.uses_global_client, traces)
