"""repro.scenario — client-population scenarios for the sharded Engine.

* :mod:`repro.scenario.profiles` — ``ClientProfile`` / ``ScenarioConfig``
  + the deterministic ``ProfileStream`` churn generators (uniform,
  pareto-straggler, diurnal-churn).
* :mod:`repro.scenario.population` — the population simulator: N (100k+)
  lazily-materialized synthetic clients driving one sharded server
  (import it directly; it pulls in ``repro.api``).
"""
from repro.scenario.profiles import (STREAMS, ClientProfile,
                                     DiurnalChurnStream,
                                     ParetoStragglerStream, ProfileStream,
                                     RoundEvents, ScenarioConfig,
                                     UniformStream, build_profile_stream,
                                     scenario_kinds)

__all__ = [
    "ClientProfile", "ScenarioConfig", "ProfileStream", "RoundEvents",
    "UniformStream", "ParetoStragglerStream", "DiurnalChurnStream",
    "STREAMS", "build_profile_stream", "scenario_kinds",
]
