"""Population simulator: very large simulated client fleets against one
sharded server.

The paper's experiments run 100-ish clients; a production split-learning
service sees orders of magnitude more, most of them tiny.  This module
makes that regime cheap to simulate:

* :class:`PopulationSpec` + :class:`PopulationFed` — N (100k+) synthetic
  clients whose data is **lazily materialized**: a client's few samples
  are generated from a fold-in of ``(seed, client_id)`` the first time a
  cohort touches it, so building a 100 000-client federation costs
  nothing and a whole run only ever materializes the clients that
  actually attended.  The API is exactly :class:`FederatedDataset`
  (``clients[c].sample_batch``, ``test_arrays``), so the unmodified
  Engine drives it.
* :func:`build_population` — the ``(task, fed, metric_key)`` triple:
  a small MLP split task over the virtual federation.
* :func:`run_population` — one scenario run: population + scenario
  config -> Engine -> rounds/sec + final eval + churn telemetry (the
  record ``benchmarks/bench_population.py`` sweeps into
  ``BENCH_population.json``).

Scale notes: the per-round cost is set by the cohort capacity (the
[C, b, ...] stacks the mesh shards over its batch axes), NOT by N — the
fleet only enters through cohort sampling (O(C) uniform, O(N) weighted)
and the lazily-touched client cache.  Global-client algorithms
(cyclesfl/sflv1/...) hold ONE shared θ_C regardless of N and are the
default here; per-client-store algorithms (psl family) allocate an
[N, ...] stack — fine at 100k for the tiny population model, but that
stack is the thing to shard next (see ROADMAP multi-host item).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.split import make_stage_task
from repro.data.federated import ClientData, FederatedDataset
from repro.models.cnn import mlp
from repro.scenario.profiles import ScenarioConfig

_CLIENT_SALT = 0x9091
_TEST_SALT = 0x9092


@dataclass(frozen=True)
class PopulationSpec:
    """A synthetic client population: class-prototype Gaussians with
    per-client style shift + Dirichlet label skew (the same failure
    modes as :mod:`repro.data.synthetic`, minus the stored arrays)."""

    n_clients: int = 100_000
    d_in: int = 32
    n_classes: int = 8
    samples_per_client: int = 16      # tiny on purpose: fleet, not corpus
    alpha: float = 0.5                # Dirichlet label-skew strength
    style_scale: float = 0.5
    noise: float = 0.3
    test_size: int = 2048             # pooled sample-wise test set
    seed: int = 0


class _LazyClients:
    """Sequence view over the virtual fleet: ``clients[c]`` materializes
    (and caches) that one client's :class:`ClientData`."""

    def __init__(self, fed: "PopulationFed"):
        self._fed = fed

    def __len__(self) -> int:
        return self._fed.spec.n_clients

    def __getitem__(self, c: int) -> ClientData:
        return self._fed.materialize(int(c))

    def __iter__(self):
        for c in range(len(self)):
            yield self[c]


class PopulationFed(FederatedDataset):
    """A :class:`FederatedDataset` whose clients exist only on demand.

    Every client's samples are a pure function of ``(spec.seed, id)``:
    ``x = proto[label] + style[id] + noise``, labels Dirichlet-skewed per
    client.  ``test_arrays`` returns one pooled population-level test
    set (size capped at ``spec.test_size``) drawn from held-out per-id
    streams, so the Engine's global eval path never concatenates N
    client test shards.
    """

    def __init__(self, spec: PopulationSpec):
        self.spec = spec
        self.clients = _LazyClients(self)
        self._cache: dict[int, ClientData] = {}
        self._test: Optional[tuple[np.ndarray, np.ndarray]] = None
        rng = np.random.default_rng([spec.seed & 0xFFFFFFFF, _CLIENT_SALT])
        protos = rng.normal(size=(spec.n_classes, spec.d_in))
        protos /= np.linalg.norm(protos, axis=1, keepdims=True)
        self._protos = (protos * np.sqrt(spec.d_in) * 0.5).astype(np.float32)

    # ------------------------------------------------------------- fleet
    @property
    def n_clients(self) -> int:
        return self.spec.n_clients

    @property
    def materialized(self) -> int:
        """How many clients a run actually touched (cache size)."""
        return len(self._cache)

    def _generate(self, c: int, rng: np.random.Generator, n: int
                  ) -> tuple[np.ndarray, np.ndarray]:
        spec = self.spec
        probs = rng.dirichlet(np.full(spec.n_classes, spec.alpha))
        labels = rng.choice(spec.n_classes, size=n, p=probs)
        style = (rng.normal(size=spec.d_in) * spec.style_scale
                 ).astype(np.float32)
        x = (self._protos[labels] + style
             + spec.noise * rng.normal(size=(n, spec.d_in))
             ).astype(np.float32)
        return x, labels.astype(np.int64)

    def materialize(self, c: int) -> ClientData:
        got = self._cache.get(c)
        if got is not None:
            return got
        spec = self.spec
        if not 0 <= c < spec.n_clients:
            raise IndexError(f"client {c} out of range [0, {spec.n_clients})")
        rng = np.random.default_rng([spec.seed & 0xFFFFFFFF,
                                     _CLIENT_SALT, c])
        n = spec.samples_per_client
        n_test = max(1, n // 10)                 # paper's 90/10 split
        x, y = self._generate(c, rng, n)
        data = ClientData(x[n_test:], y[n_test:], x[:n_test], y[:n_test])
        self._cache[c] = data
        return data

    def test_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        if self._test is None:
            spec = self.spec
            rng = np.random.default_rng([spec.seed & 0xFFFFFFFF, _TEST_SALT])
            ids = rng.choice(spec.n_clients,
                             size=min(spec.test_size, spec.n_clients),
                             replace=spec.test_size > spec.n_clients)
            xs, ys = [], []
            for c in np.unique(ids):
                # held-out stream per sampled id (disjoint salt from the
                # train stream by construction: extra draw count)
                r = np.random.default_rng([spec.seed & 0xFFFFFFFF,
                                           _TEST_SALT, int(c)])
                k = int((ids == c).sum())
                x, y = self._generate(int(c), r, k)
                xs.append(x)
                ys.append(y)
            self._test = (np.concatenate(xs), np.concatenate(ys))
        return self._test


# ---------------------------------------------------------------- builders
def build_population(spec: PopulationSpec, width: int = 32, cut: int = 1):
    """(task, fed, metric_key) for a population run: a small MLP split at
    ``cut`` over the lazy federation — the lightweight client stack the
    mesh vmaps/shards over its cohort axis."""
    model = mlp(spec.d_in, [width], spec.n_classes)
    task = make_stage_task(model, cut=cut, kind="xent")
    return task, PopulationFed(spec), "accuracy"


def population_config(spec: PopulationSpec, scenario: ScenarioConfig,
                      cohort: int = 32, rounds: int = 10, batch: int = 8,
                      **overrides):
    """An ExperimentConfig sized for the fleet: attendance is derived
    from the target cohort so capacity stays accelerator-friendly while
    N scales to hundreds of thousands."""
    from repro.api.config import ExperimentConfig
    return ExperimentConfig(
        algo=overrides.pop("algo", "cyclesfl"),
        n_clients=spec.n_clients,
        attendance=cohort / spec.n_clients,
        min_cohort=min(2, cohort), batch=batch, rounds=rounds,
        seed=spec.seed, eval_every=max(rounds, 1),
        collect_timing=True, scenario=scenario, **overrides)


def run_population(spec: PopulationSpec, scenario: ScenarioConfig,
                   cohort: int = 32, rounds: int = 10, batch: int = 8,
                   width: int = 32, log=lambda *a, **k: None,
                   **overrides) -> dict:
    """One population-scale scenario run; returns the Engine result plus
    the population/scale facts the bench harness records."""
    from repro.api.engine import Engine
    task, fed, mk = build_population(spec, width=width)
    cfg = population_config(spec, scenario, cohort=cohort, rounds=rounds,
                            batch=batch, **overrides)
    eng = Engine(cfg, task=task, fed=fed, metric_key=mk, log=log)
    res = eng.run()
    # the pipelined schedule never runs the monolithic round — its trace
    # budget is the max over the (extract, tail) dispatch pair instead
    traces = (max(eng.pipeline.extract_traces, eng.pipeline.tail_traces)
              if eng.pipeline is not None else eng.algo.trace_count)
    res["population"] = {
        "n_clients": spec.n_clients,
        "cohort_capacity": eng.cohort_capacity,
        "clients_materialized": fed.materialized,
        "trace_count": traces,
        "scenario": scenario.to_dict(),
    }
    return res
