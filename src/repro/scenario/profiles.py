"""Client population profiles + deterministic per-round churn streams.

The paper's protocol (non-iid data, partial attendance) is the *easy*
corner of what a production split-learning fleet sees.  This module adds
the missing axes as data, not as new execution paths:

* :class:`ClientProfile` — per-client compute multiplier, bandwidth,
  mid-round dropout hazard, and staleness bound.
* :class:`ProfileStream` — deterministic, seedable generators (uniform,
  pareto-straggler, diurnal-churn) that emit per-round, per-cohort-slot
  **drop** and **lag** events as plain numpy arrays, plus optional
  per-round attendance *weights* for cohort sampling.
* :class:`ScenarioConfig` — the serializable knob block that rides
  ``ExperimentConfig.scenario`` (``to_dict``/``from_dict``/flags).

Design rule: churn folds into machinery the Engine already has.  A
mid-round dropout zeroes the slot's entry in the compile-once attendance
mask *before* ``ServerUpdate`` consumes its pooled features and before
``Commit`` writes it back — exactly the padded-slot semantics, so shapes
(and therefore the XLA trace) never change.  A straggler whose drawn
delivery lag exceeds its staleness bound misses the round (dropped); one
within the bound delivers against the bounded-stale snapshot the
pipelined schedule already carries (``pipeline_staleness='async'`` = the
θ snapshot is exactly one round old).  The null scenario
(``kind='none'``) builds no stream at all — the Engine path is
bit-for-bit the scenario-free one.

Determinism contract: every stream draw is keyed by
``(scenario seed, salt, round)`` through ``np.random.default_rng`` — a
pure fold-in, never a stateful stream — so ``events(rnd, cohort)`` is
identical under replay regardless of call order or history (resume
needs no event replay; the property suite pins this).
"""
from __future__ import annotations

import argparse
from dataclasses import asdict, dataclass, fields
from typing import NamedTuple, Optional

import numpy as np

# fixed fold-in salts (never derived from hash(): PYTHONHASHSEED-proof)
_PROFILE_SALT = 0x5C11
_EVENT_SALT = 0x5C12


@dataclass(frozen=True)
class ClientProfile:
    """One simulated client's capability/behaviour profile.

    ``compute`` multiplies the client's service time (1 = nominal, 2 =
    half speed); ``bandwidth`` in (0, 1] divides its delivery speed;
    ``dropout_hazard`` is the per-round probability of a mid-round
    dropout (features extracted but never delivered); ``staleness_bound``
    is the largest delivery lag (in rounds) the protocol tolerates for
    this client before its contribution misses the round entirely.
    ``phase`` is the diurnal availability phase (radians).
    """
    compute: float = 1.0
    bandwidth: float = 1.0
    dropout_hazard: float = 0.0
    staleness_bound: int = 1
    phase: float = 0.0


class RoundEvents(NamedTuple):
    """Per-cohort-slot churn events for ONE round.

    ``keep`` ([C] float32) is 1.0 for slots that survive the round and
    0.0 for mid-round drops — the Engine multiplies it into the padded
    attendance mask, so a dropped slot's features never reach a valid
    server minibatch and its commit is skipped (padded-slot machinery).
    ``lag`` ([C] int) is each surviving slot's drawn delivery lag in
    rounds (0 = delivers within its round); slots whose draw exceeded
    their staleness bound appear with ``keep == 0``.
    """
    keep: np.ndarray
    lag: np.ndarray
    hazard_drops: int                 # slots lost to mid-round dropout
    deadline_drops: int               # slots lost to lag > staleness bound


@dataclass(frozen=True)
class ScenarioConfig:
    """Serializable description of a client-population scenario.

    ``kind='none'`` (the default) is the null scenario: no stream is
    built and the Engine runs its scenario-free path bit-for-bit.
    """
    kind: str = "none"                # none | uniform | pareto-straggler
                                      # | diurnal-churn
    dropout: float = 0.0              # base mid-round dropout hazard
    straggler: float = 0.0            # mean service lag (rounds) at
                                      # nominal compute/bandwidth
    staleness_bound: int = 1          # max tolerated delivery lag
    compute_spread: float = 1.0       # compute ~ U[1, 1 + spread]
    bandwidth_spread: float = 0.75    # bandwidth ~ 1/(1 + U[0, spread])
    pareto_shape: float = 1.5         # tail index of pareto-straggler
    period: int = 48                  # diurnal period (rounds)
    amplitude: float = 0.8            # diurnal availability swing [0, 1)
    seed: Optional[int] = None        # stream seed (None = experiment seed)

    # -------------------------------------------------------- round-trips
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioConfig":
        d = dict(d)
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown ScenarioConfig fields: {sorted(unknown)}")
        return cls(**d)

    def validate(self) -> "ScenarioConfig":
        if self.kind != "none" and self.kind not in STREAMS:
            raise KeyError(f"unknown scenario kind {self.kind!r}: "
                           f"{scenario_kinds()}")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"scenario.dropout={self.dropout} must be in "
                             "[0, 1)")
        if self.straggler < 0:
            raise ValueError(f"scenario.straggler={self.straggler} must be "
                             ">= 0")
        if self.staleness_bound < 0:
            raise ValueError(f"scenario.staleness_bound="
                             f"{self.staleness_bound} must be >= 0")
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError(f"scenario.amplitude={self.amplitude} must be "
                             "in [0, 1) (availability must stay positive)")
        if self.period < 2:
            raise ValueError(f"scenario.period={self.period} must be >= 2")
        if self.pareto_shape <= 0:
            raise ValueError(f"scenario.pareto_shape={self.pareto_shape} "
                             "must be > 0")
        return self

    @property
    def churns(self) -> bool:
        """True when the scenario can shrink a live cohort mid-round
        (dropout hazard or straggler deadline misses)."""
        return self.kind != "none" and (self.dropout > 0
                                        or self.straggler > 0)

    # -------------------------------------------------------------- flags
    @staticmethod
    def add_arguments(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
        ap.add_argument("--scenario", default="none",
                        choices=scenario_kinds(),
                        help="client-population scenario driving per-round "
                             "churn (profiles -> attendance mask + lag)")
        ap.add_argument("--scenario-dropout", type=float, default=0.0,
                        help="base mid-round dropout hazard per slot-round")
        ap.add_argument("--scenario-straggler", type=float, default=0.0,
                        help="mean service lag in rounds at nominal "
                             "compute/bandwidth (0 = no stragglers)")
        ap.add_argument("--scenario-staleness-bound", type=int, default=1,
                        help="max delivery lag (rounds) before a straggler "
                             "misses the round")
        ap.add_argument("--scenario-period", type=int, default=48,
                        help="diurnal availability period in rounds")
        ap.add_argument("--scenario-amplitude", type=float, default=0.8,
                        help="diurnal availability swing in [0, 1)")
        ap.add_argument("--scenario-seed", type=int, default=None,
                        help="scenario stream seed (default: run seed)")
        return ap

    @classmethod
    def from_flags(cls, args: argparse.Namespace) -> "ScenarioConfig":
        return cls(kind=args.scenario,
                   dropout=args.scenario_dropout,
                   straggler=args.scenario_straggler,
                   staleness_bound=args.scenario_staleness_bound,
                   period=args.scenario_period,
                   amplitude=args.scenario_amplitude,
                   seed=args.scenario_seed).validate()


# ------------------------------------------------------------------ streams
class ProfileStream:
    """Deterministic per-round churn generator over a fixed population.

    Subclasses implement ``_init_profiles`` (drawn ONCE from the profile
    fold-in stream) and may override ``hazard_at``/``weights`` for
    time-varying behaviour.  All arrays are numpy — the stream runs on
    the host, feeding values (never shapes) into the jitted round.
    """

    kind = "base"

    def __init__(self, cfg: ScenarioConfig, n_clients: int, seed: int):
        self.cfg = cfg.validate()
        self.n = int(n_clients)
        self.seed = int(cfg.seed if cfg.seed is not None else seed)
        self.phase = np.zeros(self.n)
        self._init_profiles(self._rng(_PROFILE_SALT))

    # deterministic fold-in: a fresh Generator per (seed, salt, round)
    def _rng(self, *salt: int) -> np.random.Generator:
        return np.random.default_rng([int(s) & 0xFFFFFFFF for s in
                                      (self.seed, *salt)])

    def _init_profiles(self, rng: np.random.Generator) -> None:
        raise NotImplementedError

    # ------------------------------------------------------------ queries
    def profile(self, client: int) -> ClientProfile:
        return ClientProfile(compute=float(self.compute[client]),
                             bandwidth=float(self.bandwidth[client]),
                             dropout_hazard=float(self.hazard[client]),
                             staleness_bound=int(self.bound[client]),
                             phase=float(self.phase[client]))

    @property
    def churns(self) -> bool:
        return self.cfg.churns

    def weights(self, rnd: int) -> Optional[np.ndarray]:
        """Per-client attendance weights for round ``rnd`` (``None`` =
        uniform — the sampler then makes exactly the draws the
        scenario-free Engine makes, keeping the null path bit-for-bit)."""
        return None

    def hazard_at(self, rnd: int, cohort: np.ndarray) -> np.ndarray:
        """Per-slot mid-round dropout hazard for round ``rnd``."""
        return self.hazard[cohort]

    # ------------------------------------------------------------- events
    def events(self, rnd: int, cohort, min_live: int = 1) -> RoundEvents:
        """Drop/lag events for one round's live cohort slots.

        A slot drops when (a) its hazard uniform fires (mid-round
        dropout) or (b) its drawn delivery lag exceeds its staleness
        bound (it cannot deliver inside the tolerated window).  At least
        ``min_live`` slots always survive: the most-survivable dropped
        slots (largest hazard margin) are deterministically revived, a
        revived straggler delivering exactly at its bound — so a churny
        round can never hand the server an empty feature pool.

        All draws come from ``rng([seed, EVENT_SALT, rnd])`` in a fixed
        order, so the result is a pure function of (seed, round, cohort).
        """
        cohort = np.asarray(cohort)
        c = len(cohort)
        rng = self._rng(_EVENT_SALT, rnd)
        u = rng.random(c)                        # hazard uniforms
        raw = rng.exponential(size=c)            # service-time draws
        hz = np.asarray(self.hazard_at(rnd, cohort), np.float64)
        hazard_drop = u < hz
        lag = np.zeros(c, np.int64)
        if self.cfg.straggler > 0:
            lag = np.floor(raw * self.cfg.straggler * self.compute[cohort]
                           / self.bandwidth[cohort]).astype(np.int64)
        bound = self.bound[cohort]
        deadline_drop = ~hazard_drop & (lag > bound)
        keep = ~(hazard_drop | deadline_drop)
        floor = min(int(min_live), c)
        if keep.sum() < floor:
            for i in np.argsort(hz - u):         # most survivable first
                if keep.sum() >= floor:
                    break
                if not keep[i]:
                    keep[i] = True
                    hazard_drop[i] = deadline_drop[i] = False
                    lag[i] = min(lag[i], bound[i])
        return RoundEvents(keep.astype(np.float32), lag,
                           int(hazard_drop.sum()), int(deadline_drop.sum()))


class UniformStream(ProfileStream):
    """Homogeneous-in-law heterogeneity: compute/bandwidth drawn iid
    uniform, constant dropout hazard, uniform attendance.  With zero
    dropout/straggler this stream is a structural no-op — the Engine run
    is bit-for-bit the null scenario (pinned by tests/test_scenario.py).
    """

    kind = "uniform"

    def _init_profiles(self, rng):
        cfg = self.cfg
        self.compute = 1.0 + rng.random(self.n) * cfg.compute_spread
        self.bandwidth = 1.0 / (1.0 + rng.random(self.n)
                                * cfg.bandwidth_spread)
        self.hazard = np.full(self.n, cfg.dropout)
        self.bound = np.full(self.n, cfg.staleness_bound, np.int64)


class ParetoStragglerStream(ProfileStream):
    """Heavy-tailed compute (Pareto): a small fraction of clients is
    much slower than the fleet median — the classic straggler regime
    (arxiv 2411.13907).  Slow links also drop more (hazard scales with
    1/bandwidth)."""

    kind = "pareto-straggler"

    def _init_profiles(self, rng):
        cfg = self.cfg
        self.compute = 1.0 + rng.pareto(cfg.pareto_shape, self.n)
        self.bandwidth = 1.0 / (1.0 + rng.random(self.n)
                                * cfg.bandwidth_spread)
        self.hazard = np.clip(cfg.dropout / self.bandwidth, 0.0, 0.95)
        self.bound = np.full(self.n, cfg.staleness_bound, np.int64)


class DiurnalChurnStream(UniformStream):
    """Diurnal availability: each client's attendance weight follows a
    sinusoid with a private phase (time zones), and the dropout hazard
    rises when availability is low (a client sampled near its trough is
    the one most likely to vanish mid-round)."""

    kind = "diurnal-churn"

    def _init_profiles(self, rng):
        super()._init_profiles(rng)
        self.phase = rng.uniform(0.0, 2.0 * np.pi, self.n)

    def availability(self, rnd: int) -> np.ndarray:
        cfg = self.cfg
        return 1.0 + cfg.amplitude * np.sin(
            2.0 * np.pi * rnd / cfg.period + self.phase)

    def weights(self, rnd: int) -> np.ndarray:
        a = self.availability(rnd)
        return a / a.sum()

    def hazard_at(self, rnd: int, cohort: np.ndarray) -> np.ndarray:
        return np.clip(self.hazard[cohort]
                       * (2.0 - self.availability(rnd)[cohort]), 0.0, 0.95)


STREAMS: dict[str, type] = {
    s.kind: s for s in (UniformStream, ParetoStragglerStream,
                        DiurnalChurnStream)
}


def scenario_kinds() -> tuple[str, ...]:
    return ("none",) + tuple(sorted(STREAMS))


def build_profile_stream(cfg: ScenarioConfig, n_clients: int,
                         seed: int) -> Optional[ProfileStream]:
    """Resolve a ScenarioConfig into a stream; ``None`` for the null
    scenario (the Engine then runs its scenario-free path untouched)."""
    cfg.validate()
    if cfg.kind == "none":
        return None
    return STREAMS[cfg.kind](cfg, n_clients, seed)
