"""Serializable serving knobs (rides ``ExperimentConfig.serve``).

One :class:`ServeConfig` describes the continuous-batching runtime in
:mod:`repro.serve.runtime`: the static slot-table capacity the decode
trace compiles against, the prompt/generation budgets every request is
padded to, and the robustness policy (per-request deadlines, dispatch
retry with exponential backoff).  Like the scenario/resilience configs
it round-trips losslessly through ``to_dict``/``from_dict`` and hangs
off :class:`~repro.api.config.ExperimentConfig` so serving deployments
ride the same JSON sweep files as training runs.
"""
from __future__ import annotations

import argparse
from dataclasses import asdict, dataclass, fields


@dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching serve runtime knobs.

    * ``slots`` — static slot-table capacity: the ONE decode trace is
      compiled for exactly this many concurrent sequences; admission and
      retirement ride a live-slot mask (the training arc's
      attendance-mask idiom), never a new trace.
    * ``max_prompt_len`` / ``max_new_tokens`` — static per-request
      budgets every prompt/generation is padded to (requests above the
      prompt budget are rejected at submit).
    * ``prefill_batch`` — admission chunk width: queued requests are
      prefilled ``prefill_batch`` at a time in ONE scanned dispatch.
    * ``deadline_s`` — default per-request deadline (overridable per
      submit): expired queued requests are rejected before consuming
      compute; expired in-flight requests are evicted at the next tick.
    * ``max_retries`` / ``backoff_base_s`` — failed dispatches retry up
      to ``max_retries`` times, sleeping ``backoff_base_s * 2^attempt``
      between attempts; exhaustion evicts the affected slots and leaves
      the runtime serving.
    """
    slots: int = 8
    max_prompt_len: int = 16
    max_new_tokens: int = 16
    prefill_batch: int = 4
    deadline_s: float = 60.0
    max_retries: int = 2
    backoff_base_s: float = 0.0

    # -------------------------------------------------------- round-trips
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServeConfig":
        d = dict(d)
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown ServeConfig fields: {sorted(unknown)}")
        return cls(**d)

    def validate(self) -> "ServeConfig":
        if self.slots < 1:
            raise ValueError(f"serve.slots={self.slots} must be >= 1")
        if self.max_prompt_len < 1:
            raise ValueError(f"serve.max_prompt_len={self.max_prompt_len} "
                             "must be >= 1")
        if self.max_new_tokens < 1:
            raise ValueError(f"serve.max_new_tokens={self.max_new_tokens} "
                             "must be >= 1")
        if not 1 <= self.prefill_batch <= self.slots:
            raise ValueError(
                f"serve.prefill_batch={self.prefill_batch} must be in "
                f"[1, slots={self.slots}] (admission scatters one chunk "
                "into distinct slots)")
        if self.deadline_s <= 0:
            raise ValueError(f"serve.deadline_s={self.deadline_s} must be "
                             "> 0")
        if self.max_retries < 0:
            raise ValueError(f"serve.max_retries={self.max_retries} must "
                             "be >= 0")
        if self.backoff_base_s < 0:
            raise ValueError(f"serve.backoff_base_s={self.backoff_base_s} "
                             "must be >= 0")
        return self

    # -------------------------------------------------------------- flags
    @staticmethod
    def add_arguments(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
        ap.add_argument("--serve-slots", type=int, default=8,
                        help="static decode slot-table capacity (one trace "
                             "serves any arrival pattern at this width)")
        ap.add_argument("--serve-max-prompt-len", type=int, default=16,
                        help="static prompt budget requests are padded to")
        ap.add_argument("--serve-max-new-tokens", type=int, default=16,
                        help="static generation budget per request")
        ap.add_argument("--serve-prefill-batch", type=int, default=4,
                        help="admission chunk width (one scanned prefill "
                             "dispatch per chunk)")
        ap.add_argument("--serve-deadline-s", type=float, default=60.0,
                        help="default per-request deadline in seconds")
        ap.add_argument("--serve-max-retries", type=int, default=2,
                        help="dispatch retries before evicting the "
                             "affected slots")
        ap.add_argument("--serve-backoff-base-s", type=float, default=0.0,
                        help="exponential-backoff base between dispatch "
                             "retries (seconds)")
        return ap

    @classmethod
    def from_flags(cls, args: argparse.Namespace) -> "ServeConfig":
        return cls(slots=args.serve_slots,
                   max_prompt_len=args.serve_max_prompt_len,
                   max_new_tokens=args.serve_max_new_tokens,
                   prefill_batch=args.serve_prefill_batch,
                   deadline_s=args.serve_deadline_s,
                   max_retries=args.serve_max_retries,
                   backoff_base_s=args.serve_backoff_base_s).validate()
