"""Continuous-batching split-serving subsystem.

The serving-side consumer of the training arc's mesh/config substrate:
a fixed-slot continuous-batching runtime (:mod:`repro.serve.runtime`),
its serializable knobs (:mod:`repro.serve.config`, hung off
``ExperimentConfig.serve``), and a closed-loop load generator
(:mod:`repro.serve.loadgen`) backing ``benchmarks/bench_serving.py``.
"""
from repro.serve.config import ServeConfig
from repro.serve.loadgen import make_prompts, percentiles, run_closed_loop
from repro.serve.runtime import (Request, ServeDispatchError, ServeRuntime,
                                 STATUS_DONE, STATUS_EVICTED_DEADLINE,
                                 STATUS_EVICTED_FAILURE, STATUS_QUEUED,
                                 STATUS_REJECTED, STATUS_RUNNING, TERMINAL)

__all__ = [
    "ServeConfig", "ServeRuntime", "Request", "ServeDispatchError",
    "run_closed_loop", "make_prompts", "percentiles",
    "STATUS_QUEUED", "STATUS_RUNNING", "STATUS_DONE", "STATUS_REJECTED",
    "STATUS_EVICTED_DEADLINE", "STATUS_EVICTED_FAILURE", "TERMINAL",
]
