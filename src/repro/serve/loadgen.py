"""Closed-loop load generator + latency statistics for ServeRuntime.

``run_closed_loop`` keeps exactly ``concurrency`` requests outstanding
against one runtime: each completed request is immediately replaced
until ``n_requests`` have been submitted, then the runtime drains.  A
closed loop measures the slot table's steady-state throughput at a
given client population, which is what ``BENCH_serving.json`` sweeps.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.serve.runtime import ServeRuntime, STATUS_DONE, TERMINAL


def percentiles(xs, qs=(50, 90, 99)) -> dict:
    xs = [x for x in xs if x is not None]
    if not xs:
        return {f"p{q}": None for q in qs}
    return {f"p{q}": float(np.percentile(np.asarray(xs), q)) for q in qs}


def make_prompts(n: int, max_prompt_len: int, vocab: int,
                 seed: int = 0) -> list[np.ndarray]:
    """Deterministic mixed-length prompt set (lengths 1..budget)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        ln = int(rng.integers(1, max_prompt_len + 1))
        out.append(rng.integers(0, vocab, size=ln).astype(np.int32))
    return out


def run_closed_loop(rt: ServeRuntime, prompts: list[np.ndarray], *,
                    concurrency: int, max_ticks: int = 100_000,
                    deadline_s: Optional[float] = None) -> dict:
    """Serve ``prompts`` keeping ``concurrency`` requests in flight.

    Returns a bench row: throughput (tokens/s, requests/s over the
    wall-clock window), latency and time-to-first-token percentiles
    (seconds), and terminal-status counts.
    """
    pending = list(prompts)[::-1]           # submit in order via pop()
    t0 = rt.clock()
    outstanding: set[int] = set()
    submitted: list[int] = []               # THIS call's request ids — the
    #                                         runtime's results dict is
    #                                         shared across calls on a
    #                                         reused runtime
    ticks = 0
    while pending or outstanding:
        while pending and len(outstanding) < concurrency:
            rid = rt.submit(pending.pop(), deadline_s=deadline_s)
            submitted.append(rid)
            outstanding.add(rid)
        rt.step()
        outstanding = {rid for rid in outstanding
                       if rt.results[rid].status not in TERMINAL}
        ticks += 1
        if ticks >= max_ticks:
            raise RuntimeError(f"closed loop stalled after {max_ticks} "
                               f"ticks ({len(pending)} pending, "
                               f"{len(outstanding)} outstanding)")
    elapsed = max(rt.clock() - t0, 1e-9)
    reqs = [rt.results[rid] for rid in submitted]
    done = [r for r in reqs if r.status == STATUS_DONE]
    toks = sum(len(r.tokens) for r in done)
    return {
        "concurrency": concurrency,
        "n_requests": len(prompts),
        "elapsed_s": elapsed,
        "ticks": ticks,
        "throughput_tok_s": toks / elapsed,
        "throughput_req_s": len(done) / elapsed,
        "latency_s": percentiles([r.finished - r.submitted for r in done
                                  if r.finished is not None]),
        "ttft_s": percentiles([r.first_token_t - r.submitted
                               for r in done
                               if r.first_token_t is not None]),
        "by_status": {s: sum(r.status == s for r in reqs)
                      for s in TERMINAL},
    }
