"""Continuous-batching split-serving runtime (vLLM-style slot reuse).

The server stage of the split deployment consumes concurrent client
token streams through a **fixed-capacity slot table**: ``slots``
independent decode states stacked along a slot axis, advanced together
by ONE jitted decode step per tick.  Admission and retirement are pure
masking — a retired slot is handed to the next queued request without
retracing — so the runtime compiles exactly one decode trace (plus one
prefill trace and one admission-scatter trace) regardless of how
requests arrive.  This is the serving-side twin of the training arc's
compile-once padded cohorts: the live-slot mask plays the attendance
mask's role.

Dataflow per :meth:`ServeRuntime.step` (one tick):

  1. retire   — slots whose generation budget is met hand back tokens;
  2. deadline — expired queued requests are rejected (zero compute),
                expired in-flight requests are evicted with their
                partial output;
  3. admit    — up to ``prefill_batch`` queued requests are prefilled
                in ONE scanned dispatch (a ``lax.scan`` over prompt
                positions through the same vmapped decode body — no
                per-token python loop) and scattered into free slots;
  4. decode   — one jitted step advances every live slot.

Slot-reuse correctness comes for free from the ring-buffer cache math:
:func:`repro.models.attention.attend_decode` masks cache entries via
``k_pos = pos - ((pos - slot) % C) ; valid = k_pos >= 0``, so resetting
a slot's ``pos`` to 0 at admission invalidates every stale entry the
previous occupant left behind — no cache zeroing dispatch needed (the
suite proves a reused slot is bit-for-bit a fresh runtime).

Placement: the slot table IS a decode state (``[L, S, C, Hkv, Dh]``
with the slot axis where the batch axis sits), so on a mesh it is
placed with the exact decode-state shardings ``launch/steps.py`` lowers
(:func:`repro.launch.steps.decode_state_shardings`), pinned as the
jitted tick's ``out_shardings`` so layout is stable tick-over-tick.

Robustness: every dispatch runs under a retry budget with exponential
backoff; exhaustion evicts the affected slots and the runtime keeps
serving (see :class:`~repro.serve.config.ServeConfig`).  ``clock`` /
``sleep`` / ``fault_hook`` are injectable so the deadline and backoff
paths are deterministic under test.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.transformer import Transformer
from repro.serve.config import ServeConfig
from repro.utils.tree import path_str

# request terminal states
STATUS_QUEUED = "queued"
STATUS_RUNNING = "running"
STATUS_DONE = "done"
STATUS_REJECTED = "rejected_deadline"      # expired before admission
STATUS_EVICTED_DEADLINE = "evicted_deadline"
STATUS_EVICTED_FAILURE = "evicted_failure"
TERMINAL = (STATUS_DONE, STATUS_REJECTED, STATUS_EVICTED_DEADLINE,
            STATUS_EVICTED_FAILURE)


class ServeDispatchError(RuntimeError):
    """A dispatch failed on every retry attempt."""

    def __init__(self, site: str, attempts: int, cause: Exception):
        super().__init__(f"{site} dispatch failed after {attempts} "
                         f"attempts: {cause!r}")
        self.site = site
        self.attempts = attempts
        self.cause = cause


@dataclass
class Request:
    """One client stream: prompt in, up to ``max_new`` greedy tokens out.

    The first output token is the one the prefilled prompt predicts
    (argmax of the prefill logits) — time-to-first-token is the prefill
    dispatch, not a decode tick.
    """
    rid: int
    prompt: np.ndarray                 # int32 [len], 1 <= len <= budget
    max_new: int
    deadline_s: float
    submitted: float
    status: str = STATUS_QUEUED
    admitted: Optional[float] = None
    first_token_t: Optional[float] = None
    finished: Optional[float] = None
    slot: Optional[int] = None
    retries: int = 0                   # dispatch retries this request saw
    tokens: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))

    @property
    def deadline(self) -> float:
        return self.submitted + self.deadline_s

    def record(self) -> dict:
        lat = (self.finished - self.submitted
               if self.finished is not None else None)
        ttft = (self.first_token_t - self.submitted
                if self.first_token_t is not None else None)
        return {"rid": self.rid, "status": self.status,
                "prompt_len": int(len(self.prompt)),
                "n_tokens": int(len(self.tokens)),
                "latency_s": lat, "ttft_s": ttft, "retries": self.retries}


class ServeRuntime:
    """Fixed-slot continuous-batching server for decoder-only archs."""

    def __init__(self, arch: ArchConfig, serve: ServeConfig, *,
                 params=None, seed: int = 0, mesh=None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 fault_hook: Optional[Callable[[str, int, int], None]] = None,
                 log=None):
        if arch.family == "audio":
            raise ValueError("ServeRuntime serves decoder-only archs; "
                             "audio (enc-dec) uses launch.serve.serve_whisper")
        self.arch = arch
        self.serve = serve.validate()
        self.mesh = mesh
        self.clock = clock
        self.sleep = sleep
        self.fault_hook = fault_hook
        self.log = log or (lambda *a: None)
        self.slots = serve.slots
        self.max_new = serve.max_new_tokens
        self.cap = serve.max_prompt_len + serve.max_new_tokens

        from repro.sharding.specs import set_activation_mesh
        set_activation_mesh(mesh)
        if params is None:
            params = Transformer.init(jax.random.PRNGKey(seed), arch)
        self.params = params

        # ---- slot-axis rules: the slot table is a decode state with the
        # batch dim as the slot dim; per-sequence scalars (pos, ring idx)
        # stack along a fresh leading axis.  Paths are the checkpoint /
        # steps.py '/'-joined paths, so the decode-state sharding rules
        # apply to the table verbatim.
        mono = jax.eval_shape(
            lambda: Transformer.init_decode_state(arch, 1, self.cap))
        self._axis: dict[str, int] = {}     # path -> slot axis in the table
        self._stacked: dict[str, bool] = {}  # False: scalar-derived leaf
        flat, _ = jax.tree_util.tree_flatten_with_path(mono)
        for kp, leaf in flat:
            p = path_str(kp)
            stacked = len(leaf.shape) > 0
            ax = 1 if stacked else 0    # every batched decode leaf is
            self._axis[p] = ax          # [L, B, ...]; scalars become [S]
            self._stacked[p] = stacked
            assert not stacked or leaf.shape[1] == 1, p
        self.state = self._zero_slot_state(self.slots)
        self.cur_tok = jnp.zeros((self.slots,), jnp.int32)
        self.counts = jnp.zeros((self.slots,), jnp.int32)
        self.out_buf = jnp.zeros((self.slots, self.max_new), jnp.int32)
        self._chunk_zero = self._zero_slot_state(serve.prefill_batch)

        # ---- compile-once claim instrumentation: each counter counts
        # python-body executions of a jitted function = XLA traces
        self.traces = {"prefill": 0, "admit": 0, "decode": 0}
        self._build_steps()
        if mesh is not None:
            self._place_on_mesh()

        # ---- host-side scheduler state
        self.queue: deque[Request] = deque()
        self.slot_req: list[Optional[Request]] = [None] * self.slots
        self.free: list[int] = list(range(self.slots))[::-1]
        self.counts_host = np.zeros(self.slots, np.int64)
        self.results: dict[int, Request] = {}
        self.assignments = np.zeros(self.slots, np.int64)
        self._tick = 0
        self._next_rid = 0
        self.dispatch_retries = 0
        self.evictions = {"deadline": 0, "failure": 0, "rejected": 0}

    # ------------------------------------------------------------ build
    def _zero_slot_state(self, n: int):
        mono = jax.eval_shape(
            lambda: Transformer.init_decode_state(self.arch, 1, self.cap))

        def leaf(kp, l):
            p = path_str(kp)
            if not self._stacked.get(p, len(l.shape) > 0):
                return jnp.zeros((n,), l.dtype)
            shape = list(l.shape)
            shape[1] = n
            return jnp.zeros(shape, l.dtype)

        return jax.tree_util.tree_map_with_path(leaf, mono)

    def _slot_ax(self, p: str) -> int:
        return self._axis[p] if self._stacked[p] else 0

    def _where_slot(self, mask, new, old):
        """Per-slot select over a slot-table pytree (mask [S] bool)."""

        def sel(kp, n, o):
            ax = self._slot_ax(path_str(kp))
            shape = [1] * n.ndim
            shape[ax] = n.shape[ax]
            return jnp.where(mask.reshape(shape), n, o)

        return jax.tree_util.tree_map_with_path(sel, new, old)

    def _build_steps(self):
        arch = self.arch
        axes = jax.tree_util.tree_map_with_path(
            lambda kp, _: self._slot_ax(path_str(kp)), self.state)

        def one(params, tok, state):
            # inner adapter: re-insert the singleton batch dim the
            # unchanged decode_step expects; per-sequence scalars (pos,
            # ring idx) arrive already scalar from the slot axis
            full = jax.tree_util.tree_map_with_path(
                lambda kp, l: (jnp.expand_dims(l, self._axis[path_str(kp)])
                               if self._stacked[path_str(kp)] else l), state)
            logits, new = Transformer.decode_step(params, arch, tok[None],
                                                  full)
            new = jax.tree_util.tree_map_with_path(
                lambda kp, l: (jnp.squeeze(l, self._axis[path_str(kp)])
                               if self._stacked[path_str(kp)] else l), new)
            return logits[0], new

        # one decode body, vmapped over the slot axis — tok [S,1],
        # state slot-table -> (logits [S,1,V], state')
        self._vstep = jax.vmap(one, in_axes=(None, 0, axes),
                               out_axes=(0, axes))
        S, M, Pb = self.slots, self.max_new, self.serve.prefill_batch
        P = self.serve.max_prompt_len

        def decode_fn(params, state, cur_tok, live, counts, out_buf):
            self.traces["decode"] += 1
            lg, st2 = self._vstep(params, cur_tok[:, None], state)
            state = self._where_slot(live, st2, state)
            tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
            tok = jnp.where(live, tok, cur_tok)
            idx = jnp.clip(counts, 0, M - 1)
            rows = jnp.arange(S)
            out_buf = out_buf.at[rows, idx].set(
                jnp.where(live, tok, out_buf[rows, idx]))
            counts = counts + live.astype(jnp.int32)
            return state, tok, counts, out_buf

        def prefill_fn(params, tokens, lens, state):
            # batched prefill: ONE dispatch scans the whole prompt
            # budget through the same vmapped decode body, masking rows
            # past their length — bit-equal to per-token stepping by
            # construction (jnp.where passes the active rows' bits
            # through untouched)
            self.traces["prefill"] += 1

            def body(carry, i):
                st, logits = carry
                tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
                lg, st2 = self._vstep(params, tok, st)
                st = self._where_slot(i < lens, st2, st)
                logits = jnp.where((i == lens - 1)[:, None, None], lg,
                                   logits)
                return (st, logits), None

            init = (state, jnp.zeros((Pb, 1, arch.vocab), jnp.float32))
            (st, logits), _ = jax.lax.scan(
                body, init, jnp.arange(P, dtype=jnp.int32))
            first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return st, first

        def admit_fn(state, cur_tok, counts, out_buf, cstate, first,
                     slot_ids, admit):
            # scatter a prefilled chunk into its (host-chosen, distinct)
            # slots; non-admitted rows carry unused slot ids and write
            # their targets' own values back (a structural no-op)
            self.traces["admit"] += 1

            def sc(kp, leaf, cleaf):
                p = path_str(kp)
                if self._stacked[p]:
                    m = admit.reshape((1, -1) + (1,) * (leaf.ndim - 2))
                    upd = jnp.where(m, cleaf, leaf[:, slot_ids])
                    return leaf.at[:, slot_ids].set(upd)
                m = admit.reshape((-1,) + (1,) * (leaf.ndim - 1))
                upd = jnp.where(m, cleaf, leaf[slot_ids])
                return leaf.at[slot_ids].set(upd)

            state = jax.tree_util.tree_map_with_path(sc, state, cstate)
            cur_tok = cur_tok.at[slot_ids].set(
                jnp.where(admit, first, cur_tok[slot_ids]))
            counts = counts.at[slot_ids].set(
                jnp.where(admit, 1, counts[slot_ids]))
            out_buf = out_buf.at[slot_ids, 0].set(
                jnp.where(admit, first, out_buf[slot_ids, 0]))
            return state, cur_tok, counts, out_buf

        if self.mesh is None:
            self._decode = jax.jit(decode_fn)
            self._prefill = jax.jit(prefill_fn)
            self._admit = jax.jit(admit_fn)
            return
        # mesh placement: the slot table takes the decode-state rules
        # from launch/steps.py verbatim (slot axis = batch axis), the
        # per-slot vectors ride the batch axes, and every tick's outputs
        # are pinned to the same shardings so layout is stable
        from jax.sharding import NamedSharding
        from repro.launch.steps import (_batch_leading_spec,
                                        decode_state_shardings)
        s_state = decode_state_shardings(self.state, self.mesh)
        s_chunk = decode_state_shardings(self._chunk_zero, self.mesh)

        def vec(shape):
            return NamedSharding(self.mesh, _batch_leading_spec(
                self.mesh, shape, len(shape) - 1))

        s_tok, s_counts = vec((S,)), vec((S,))
        s_buf, s_first = vec((S, M)), vec((Pb,))
        self._decode = jax.jit(
            decode_fn,
            out_shardings=(s_state, s_tok, s_counts, s_buf))
        self._prefill = jax.jit(prefill_fn,
                                out_shardings=(s_chunk, s_first))
        self._admit = jax.jit(
            admit_fn, out_shardings=(s_state, s_tok, s_counts, s_buf))
        self._s_state, self._s_chunk, self._vec = s_state, s_chunk, vec

    def _place_on_mesh(self):
        from repro.launch.steps import _ns
        from repro.sharding.specs import param_specs
        moe_mode = self.arch.moe.shard_mode if self.arch.moe else "expert"
        self.params = jax.device_put(
            self.params,
            _ns(self.mesh, param_specs(self.params, self.mesh, "full",
                                       moe_mode)))
        self.state = jax.device_put(self.state, self._s_state)
        self._chunk_zero = jax.device_put(self._chunk_zero, self._s_chunk)
        self.cur_tok = jax.device_put(self.cur_tok, self._vec((self.slots,)))
        self.counts = jax.device_put(self.counts, self._vec((self.slots,)))
        self.out_buf = jax.device_put(
            self.out_buf, self._vec((self.slots, self.max_new)))

    # --------------------------------------------------------- dispatch
    def _dispatch(self, site: str, fn, *args):
        """Run one jitted dispatch under the retry/backoff budget."""
        last = None
        for attempt in range(self.serve.max_retries + 1):
            try:
                if self.fault_hook is not None:
                    self.fault_hook(site, self._tick, attempt)
                out = fn(*args)
            except Exception as e:      # noqa: BLE001 — any dispatch fault
                last = e
                self.dispatch_retries += int(
                    attempt < self.serve.max_retries)
                if attempt < self.serve.max_retries:
                    if self.serve.backoff_base_s > 0:
                        self.sleep(self.serve.backoff_base_s
                                   * (2.0 ** attempt))
                    continue
                raise ServeDispatchError(site, attempt + 1, e) from e
            return out, attempt
        raise ServeDispatchError(site, self.serve.max_retries + 1, last)

    # ----------------------------------------------------------- submit
    def submit(self, prompt: Sequence[int], *, max_new: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue one request; returns its rid.  An empty prompt is a
        BOS-0 prompt (matching ``serve_decoder_only``'s prompt_len=0
        semantics: generation starts from token 0's prediction)."""
        toks = np.asarray(list(prompt) or [0], np.int32)
        if len(toks) > self.serve.max_prompt_len:
            raise ValueError(
                f"prompt of {len(toks)} tokens exceeds the static budget "
                f"serve.max_prompt_len={self.serve.max_prompt_len}")
        if (toks < 0).any() or (toks >= self.arch.vocab).any():
            raise ValueError("prompt token out of vocab range")
        mn = self.max_new if max_new is None else int(max_new)
        if not 1 <= mn <= self.max_new:
            raise ValueError(f"max_new={mn} must be in [1, "
                             f"{self.max_new}]")
        req = Request(rid=self._next_rid, prompt=toks, max_new=mn,
                      deadline_s=(self.serve.deadline_s if deadline_s is None
                                  else float(deadline_s)),
                      submitted=self.clock())
        self._next_rid += 1
        self.queue.append(req)
        self.results[req.rid] = req
        return req.rid

    # ------------------------------------------------------- scheduling
    def live_requests(self) -> list[Request]:
        return [r for r in self.slot_req if r is not None]

    @property
    def n_live(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def _retire(self, slot: int, status: str, now: float):
        req = self.slot_req[slot]
        n = int(self.counts_host[slot])
        req.tokens = np.asarray(
            jax.device_get(self.out_buf[slot, :n])).astype(np.int32)
        req.status = status
        req.finished = now
        self.slot_req[slot] = None
        self.counts_host[slot] = 0
        self.free.append(slot)

    def _evict_chunk(self, chunk: list[Request], slots: list[int],
                     attempts: int, now: float):
        for r in chunk:
            r.retries += attempts - 1
            r.status = STATUS_EVICTED_FAILURE
            r.finished = now
            self.evictions["failure"] += 1
        self.free.extend(slots)

    def step(self) -> None:
        """One scheduler tick: retire / expire / admit / decode."""
        now = self.clock()
        self._tick += 1
        # 1. retire slots whose generation budget is met
        for s, req in enumerate(self.slot_req):
            if req is not None and self.counts_host[s] >= req.max_new:
                self._retire(s, STATUS_DONE, now)
        # 2. deadlines: expired in-flight slots are evicted with their
        # partial output; expired queued requests never consume compute
        for s, req in enumerate(self.slot_req):
            if req is not None and now > req.deadline:
                self._retire(s, STATUS_EVICTED_DEADLINE, now)
                self.evictions["deadline"] += 1
        kept = deque()
        for req in self.queue:
            if now > req.deadline:
                req.status = STATUS_REJECTED
                req.finished = now
                self.evictions["rejected"] += 1
            else:
                kept.append(req)
        self.queue = kept
        # 3. admission: chunked batched prefill into free slots
        while self.queue and self.free:
            self._admit_chunk(now)
        # 4. decode: one jitted step advances every live slot
        live_idx = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not live_idx:
            return
        live = np.zeros(self.slots, bool)
        live[live_idx] = True
        try:
            (self.state, self.cur_tok, self.counts, self.out_buf), att = \
                self._dispatch("decode", self._decode, self.params,
                               self.state, self.cur_tok, jnp.asarray(live),
                               self.counts, self.out_buf)
        except ServeDispatchError:
            # decode failures carry no per-slot blame — evict every live
            # slot with its partial output and keep the runtime serving
            self.log(f"[serve] decode dispatch exhausted at tick "
                     f"{self._tick}; evicting {len(live_idx)} live slots")
            for s in live_idx:
                self.slot_req[s].retries += self.serve.max_retries
                self._retire(s, STATUS_EVICTED_FAILURE, now)
                self.evictions["failure"] += 1
            return
        if att:
            for s in live_idx:
                self.slot_req[s].retries += att
        self.counts_host[live_idx] += 1

    def _admit_chunk(self, now: float) -> None:
        Pb = self.serve.prefill_batch
        n = min(len(self.queue), len(self.free), Pb)
        chunk = [self.queue.popleft() for _ in range(n)]
        slots = [self.free.pop() for _ in range(n)]
        # pad the chunk's scatter targets with DISTINCT unused slots so
        # the jitted scatter never sees duplicate indices (Pb <= slots
        # guarantees enough spares among free + live-but-untouched)
        spare = [s for s in self.free if s not in slots]
        spare += [s for s in range(self.slots)
                  if s not in slots and s not in spare]
        slot_ids = np.asarray(slots + spare[:Pb - n], np.int32)
        admit = np.zeros(Pb, bool)
        admit[:n] = True
        tokens = np.zeros((Pb, self.serve.max_prompt_len), np.int32)
        lens = np.zeros(Pb, np.int32)
        for i, r in enumerate(chunk):
            tokens[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        try:
            (cstate, first), att = self._dispatch(
                "prefill", self._prefill, self.params,
                jnp.asarray(tokens), jnp.asarray(lens), self._chunk_zero)
        except ServeDispatchError:
            self.log(f"[serve] prefill dispatch exhausted at tick "
                     f"{self._tick}; evicting {n} queued requests")
            self._evict_chunk(chunk, slots, self.serve.max_retries + 1, now)
            return
        (self.state, self.cur_tok, self.counts, self.out_buf), _ = \
            self._dispatch("admit", self._admit, self.state, self.cur_tok,
                           self.counts, self.out_buf, cstate, first,
                           jnp.asarray(slot_ids), jnp.asarray(admit))
        t_first = self.clock()
        for i, r in enumerate(chunk):
            r.status = STATUS_RUNNING
            r.slot = slots[i]
            r.admitted = now
            r.first_token_t = t_first
            r.retries += att
            self.slot_req[slots[i]] = r
            self.counts_host[slots[i]] = 1
            self.assignments[slots[i]] += 1

    def drain(self, max_ticks: int = 100_000) -> None:
        """Step until the queue and slot table are empty."""
        ticks = 0
        while self.queue or self.n_live:
            self.step()
            ticks += 1
            if ticks >= max_ticks:
                raise RuntimeError(
                    f"serve drain made no progress in {max_ticks} ticks "
                    f"({len(self.queue)} queued, {self.n_live} live)")

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        reqs = list(self.results.values())
        by = {s: sum(r.status == s for r in reqs) for s in TERMINAL}
        return {
            "requests": len(reqs),
            "by_status": by,
            "tokens_out": int(sum(len(r.tokens) for r in reqs)),
            "ticks": self._tick,
            "dispatch_retries": self.dispatch_retries,
            "evictions": dict(self.evictions),
            "slot_assignments": self.assignments.tolist(),
            "max_slot_reuse": int(self.assignments.max(initial=0)),
            "traces": dict(self.traces),
        }

    def records(self) -> list[dict]:
        return [self.results[rid].record() for rid in sorted(self.results)]
