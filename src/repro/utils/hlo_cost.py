"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a while-loop body ONCE, regardless
of trip count (verified in tests/test_roofline.py) — useless for models
built on ``lax.scan`` layer stacks.  This module parses the optimized
HLO text instead and computes:

  * dot/convolution FLOPs  (2 · prod(out dims) · prod(contracting dims))
  * HBM traffic estimate   (operand+result bytes at fusion boundaries)
  * collective bytes       (operand bytes of all-gather/-reduce/… ops)

each multiplied by the *product of trip counts of enclosing while
loops* (nested loops multiply), following the call graph through
``body=``/``condition=``/``calls=``/``to_apply=`` edges.

Trip counts are recovered from the canonical scan lowering: the while
condition compares the induction variable against a ``constant(N)``.
Unrecognized conditions fall back to trip count 1 (undercount, never
overcount).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LAYOUT_RE = re.compile(r"\{[^{}]*\}")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast",
                  "ragged-all-to-all")

# ops whose operands/results cross the HBM boundary (post-fusion HLO)
_TRAFFIC_OPS = ("fusion", "dot", "convolution", "copy", "gather", "scatter",
                "dynamic-slice", "dynamic-update-slice", "reduce", "sort",
                "concatenate", "broadcast", "iota", "transpose", "reshape",
                "slice", "pad", "select", "compare", "add", "multiply")


def _shape_dims(s: str):
    m = _SHAPE_RE.match(s)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else (dt, [])


def _split_operands(s: str) -> list[str]:
    """Split an HLO operand list on top-level commas only (shape dims and
    layouts contain commas too: ``f32[128,64]{1,0} %arg``)."""
    out, depth, buf = [], 0, []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(buf).strip())
            buf = []
        else:
            buf.append(ch)
    if buf:
        out.append("".join(buf).strip())
    return [o for o in out if o]


def _operand_name(tok: str) -> str:
    return tok.split()[-1].lstrip("%")


def _operand_shape(tok: str, sym_shape: dict) -> tuple:
    """Dims of an operand reference: newer HLO inlines the shape
    (``f32[128,64]{1,0} %arg``); older text is a bare ``%arg`` resolved
    through the computation's symbol table."""
    first = tok.split()[0] if tok.split() else ""
    m = _SHAPE_RE.match(first)
    if m:
        dims = m.group(2)
        return tuple(int(d) for d in dims.split(",")) if dims else ()
    return sym_shape.get(_operand_name(tok), ())


def _operand_bytes(tok: str, sym_bytes: dict) -> int:
    first = tok.split()[0] if tok.split() else ""
    if _SHAPE_RE.match(first):
        return _shape_bytes(first)
    return sym_bytes.get(_operand_name(tok), 0)


def _shape_bytes(s: str) -> int:
    m = _SHAPE_RE.match(s)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in (dims.split(",") if dims else []):
        n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _all_shapes_bytes(text: str) -> int:
    return sum(_shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(text))


@dataclass
class Computation:
    name: str
    lines: list[str] = field(default_factory=list)
    # edges: (callee, kind) kind in {'body','condition','calls','to_apply'}
    edges: list[tuple[str, str]] = field(default_factory=list)
    is_fusion: bool = False


def _split_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw  # keep layouts: {…} also delimits contracting dims
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if (m and line.rstrip().endswith("{") and "->" in line
                    and "=" not in line.split("(", 1)[0]):
                cur = Computation(m.group(1))
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            cur.lines.append(line)
            for kind in ("body", "condition", "calls", "to_apply"):
                for cm in re.finditer(kind + r"=%?([\w.\-]+)", line):
                    cur.edges.append((cm.group(1), kind))
    return comps


def _trip_count(cond: Computation) -> int:
    """Recover the constant bound of a canonical counted loop."""
    consts: dict[str, int] = {}
    for line in cond.lines:
        m = re.search(r"%?([\w.\-]+)\s*=\s*\S+\s+constant\((\d+)\)", line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond.lines:
        if " compare(" not in line:
            continue
        args = re.search(r"compare\(([^)]*)\)", line)
        if not args:
            continue
        names = [a.strip().lstrip("%") for a in args.group(1).split(",")]
        for nm in names:
            if nm in consts:
                return max(1, consts[nm])
        # operand may be an inline constant reference with shape prefix
        for nm in names:
            mm = re.match(r"\S*constant\((\d+)\)", nm)
            if mm:
                return max(1, int(mm.group(1)))
    return 1


def _line_flops(line: str) -> float:
    """FLOPs of one dot/convolution HLO line."""
    dm = _DEF_RE.match(line)
    if not dm:
        return 0.0
    rhs = dm.group(2)
    out_head = rhs.split("(", 1)[0]
    if re.search(r"\bdot\b", out_head) is None and " dot(" not in rhs \
            and not re.search(r"=\s*\S+\s+dot\(", line) \
            and " convolution(" not in rhs:
        return 0.0
    _, out_dims = _shape_dims(out_head.strip().split()[0])
    if out_dims is None:
        return 0.0
    out_prod = 1
    for d in out_dims:
        out_prod *= d
    if " convolution(" in rhs:
        # approximate: 2 * out * (kernel spatial * in_channels) — parse the
        # kernel operand shape (second operand)
        args = re.search(r"convolution\(([^)]*)\)", rhs)
        return 2.0 * out_prod  # conservative; convs only in CNN benches
    # contracting dims product from the lhs operand shape + dim numbers
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    args = re.search(r"dot\(([^)]*)\)", rhs)
    if not cd or not args:
        return 2.0 * out_prod
    lhs_arg = args.group(1).split(",")[0].strip()
    # operand may be a bare name — we can't resolve shapes here, so the
    # caller passes a symbol table; handled in module_cost instead.
    return -1.0  # sentinel: needs symbol resolution


@dataclass
class ModuleCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    # per-kind census: {'sites': distinct HLO op sites, 'execs': loop-
    # multiplied executions per round, 'bytes': loop-multiplied operand
    # bytes, 'max_op_bytes': largest single-op operand bytes}
    collective_census: dict = field(default_factory=dict)
    multipliers: dict = field(default_factory=dict)

    def summary(self) -> dict:
        return {
            "flops": self.flops,
            "traffic_bytes": self.traffic_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": dict(self.collective_by_kind),
            "collective_census": {
                k: {**v, "op_bytes": sorted(v["op_bytes"])}
                for k, v in self.collective_census.items()},
        }


def module_cost(text: str) -> ModuleCost:
    comps = _split_computations(text)

    # ---- call-graph multipliers ----
    # edge weight: body -> trip count of its while; others -> 1
    trip_of_body: dict[str, int] = {}
    parents: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for comp in comps.values():
        # group body/condition pairs per while line
        for line in comp.lines:
            if " while(" not in line:
                continue
            bm = re.search(r"body=%?([\w.\-]+)", line)
            cm = re.search(r"condition=%?([\w.\-]+)", line)
            if not bm:
                continue
            tm = _TRIP_RE.search(line)  # XLA annotates counted loops
            if tm:
                trips = max(1, int(tm.group(1)))
            elif cm and cm.group(1) in comps:
                trips = _trip_count(comps[cm.group(1)])
            else:
                trips = 1
            trip_of_body[bm.group(1)] = trips
            parents[bm.group(1)].append((comp.name, trips))
            if cm:  # the condition also runs `trips` times (cheap, but
                parents[cm.group(1)].append((comp.name, trips))
        for callee, kind in comp.edges:
            if kind in ("calls", "to_apply") and callee in comps:
                parents[callee].append((comp.name, 1))

    entry = None
    for name in comps:
        if "main" in name:
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]

    mult_cache: dict[str, float] = {}

    def multiplier(name: str, depth=0) -> float:
        """Total number of executions of a computation: SUM over call
        sites of (site weight x caller multiplier).  CSE shares identical
        computations across phases, so max-over-parents undercounts."""
        if name == entry:
            return 1.0
        if name in mult_cache:
            return mult_cache[name]
        if depth > 64 or not parents[name]:
            return 1.0
        mult_cache[name] = 1.0  # cycle guard
        total = 0.0
        for parent, w in parents[name]:
            total += w * multiplier(parent, depth + 1)
        mult_cache[name] = total or 1.0
        return mult_cache[name]

    cost = ModuleCost()

    # ---- effective input bytes of fused computations ----
    # A kLoop fusion that merely dynamic-slices a big parameter (the scan
    # weight-stack idiom) reads ONE slice per call, not the whole stack.
    # effective_inputs[comp] = param_idx -> bytes actually read per call.
    effective_inputs: dict[str, dict[int, int]] = {}
    for comp in comps.values():
        params: dict[str, tuple[int, int]] = {}   # name -> (idx, full bytes)
        sym_b: dict[str, int] = {}
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            nm, rhs = dm.groups()
            sym_b[nm] = _all_shapes_bytes(rhs.split("(", 1)[0])
            pm = re.search(r"parameter\((\d+)\)", rhs)
            if pm:
                params[nm] = (int(pm.group(1)), sym_b[nm])
        if not params:
            continue
        eff: dict[int, int] = {}
        for pname, (pidx, pbytes) in params.items():
            consumers = [ln for ln in comp.lines
                         if re.search(r"[(,\s]%" + re.escape(pname) + r"[),\s]", ln)
                         and not re.search(r"%" + re.escape(pname) + r"\s*=", ln)]
            if consumers and all((" dynamic-slice(" in ln
                                  or " dynamic-update-slice(" in ln)
                                 for ln in consumers):
                sliced = 0
                for ln in consumers:
                    dm2 = _DEF_RE.match(ln)
                    if not dm2:
                        continue
                    if " dynamic-update-slice(" in ln:
                        # read slice ≈ the update operand's size
                        um = re.search(r"dynamic-update-slice\(([^)]*)\)", ln)
                        if um:
                            ops_ = _split_operands(um.group(1))
                            if len(ops_) > 1:
                                sliced += _operand_bytes(ops_[1], sym_b)
                    else:
                        sliced += _all_shapes_bytes(
                            dm2.group(2).split("(", 1)[0])
                eff[pidx] = sliced
            else:
                eff[pidx] = pbytes
        effective_inputs[comp.name] = eff

    # symbol tables per computation: name -> result-shape bytes / dims
    for comp in comps.values():
        mult = multiplier(comp.name)
        cost.multipliers[comp.name] = mult
        sym_bytes: dict[str, int] = {}
        sym_shape: dict[str, tuple] = {}
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rhs = dm.groups()
            head = rhs.split("(", 1)[0]
            sym_bytes[name] = _all_shapes_bytes(head)
            sm = _SHAPE_RE.search(head)
            if sm:
                dt, dims = sm.group(1), sm.group(2)
                sym_shape[name] = tuple(int(d) for d in dims.split(",")) \
                    if dims else ()
        is_fused = comp.name.startswith("fused_") or ".fused" in comp.name \
            or comp.name.startswith("%fused")

        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rhs = dm.groups()
            # ---- flops: dot ----
            dmatch = re.search(r"\bdot\(([^)]*)\)", rhs)
            if dmatch:
                out_dims = sym_shape.get(name, ())
                out_prod = 1
                for d in out_dims:
                    out_prod *= d
                contract = 1
                cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                dops = _split_operands(dmatch.group(1))
                lhs_shape = _operand_shape(dops[0], sym_shape) if dops else ()
                if cd and lhs_shape:
                    for di in (int(x) for x in cd.group(1).split(",") if x):
                        if di < len(lhs_shape):
                            contract *= lhs_shape[di]
                cost.flops += mult * 2.0 * out_prod * contract
            elif " convolution(" in rhs:
                out_dims = sym_shape.get(name, ())
                out_prod = 1
                for d in out_dims:
                    out_prod *= d
                cm_ = re.search(r"convolution\(([^)]*)\)", rhs)
                k_contract = 1
                if cm_:
                    ops_ = _split_operands(cm_.group(1))
                    if len(ops_) > 1:
                        ksh = _operand_shape(ops_[1], sym_shape)
                        for d in ksh[:-1]:   # all but output-feature dim
                            k_contract *= d
                cost.flops += mult * 2.0 * out_prod * k_contract

            # ---- collectives ----
            copm = re.search(
                r"\b(" + "|".join(COLLECTIVE_OPS) + r")(?:-start)?\(", rhs)
            if copm and "-done" not in rhs.split("(", 1)[0]:
                args = re.search(r"\(([^)]*)", rhs.split(copm.group(0))[-1]
                                 if False else rhs[copm.start():])
                nbytes = 0
                inner = rhs[copm.end():]
                depth, buf = 1, []
                for ch in inner:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    buf.append(ch)
                for a in _split_operands("".join(buf)):
                    nbytes += _operand_bytes(a, sym_bytes) \
                        or _all_shapes_bytes(a)
                if nbytes == 0:
                    nbytes = sym_bytes.get(name, 0)
                cost.collective_bytes += mult * nbytes
                cost.collective_by_kind[copm.group(1)] += mult * nbytes
                cen = cost.collective_census.setdefault(
                    copm.group(1), {"sites": 0, "execs": 0.0, "bytes": 0.0,
                                    "max_op_bytes": 0.0, "op_bytes": set()})
                cen["sites"] += 1
                cen["execs"] += mult
                cen["bytes"] += mult * nbytes
                cen["max_op_bytes"] = max(cen["max_op_bytes"], float(nbytes))
                cen["op_bytes"].add(int(nbytes))

            # ---- HBM traffic at fusion boundaries (non-fused comps) ----
            if not is_fused:
                head_tokens = rhs.split("(", 1)[0].strip().split()
                opname = head_tokens[-1] if ("(" in rhs and head_tokens) else ""
                if opname in ("fusion", "dot", "convolution", "copy", "gather",
                              "scatter", "dynamic-slice", "dynamic-update-slice",
                              "reduce", "sort", "concatenate", "transpose"):
                    outb = sym_bytes.get(name, 0)
                    am = re.search(re.escape(opname) + r"\(([^)]*)\)", rhs)
                    operands = []
                    if am:
                        operands = [_operand_bytes(a, sym_bytes)
                                    for a in _split_operands(am.group(1))]
                    if opname == "dynamic-update-slice":
                        # in-place: traffic = read+write of the UPDATE slice,
                        # not the full aliased buffer
                        upd = operands[1] if len(operands) > 1 else 0
                        cost.traffic_bytes += mult * 2 * upd
                    elif opname == "dynamic-slice":
                        cost.traffic_bytes += mult * 2 * outb
                    elif opname in ("gather", "scatter"):
                        cost.traffic_bytes += mult * 2 * outb
                    elif opname == "fusion":
                        callee = None
                        cm2 = re.search(r"calls=%?([\w.\-]+)", line)
                        if cm2:
                            callee = cm2.group(1)
                        eff = effective_inputs.get(callee, {})
                        inb = 0
                        for i_op, ob in enumerate(operands):
                            inb += min(eff.get(i_op, ob), ob) if eff else ob
                        cost.traffic_bytes += mult * (outb + inb)
                    else:
                        cost.traffic_bytes += mult * (outb + sum(operands))
    return cost


def collective_census(text: str) -> dict:
    """Per-kind collective census of one optimized HLO module: how many
    all-gather/all-reduce/reduce-scatter/… op SITES the compiled round
    contains, how many times they EXECUTE per round (while-loop trip
    counts multiplied through the call graph), the loop-multiplied
    operand bytes they move, and the largest single-op operand bytes.

    The census is the evidence format behind the phase-boundary
    collective surgery: ``op_bytes`` (distinct single-op operand sizes)
    is what lets :func:`assert_no_pool_allgather` distinguish a gather
    OF the feature pool from legitimate FSDP weight rehydration.
    """
    return {k: {**v, "op_bytes": sorted(v["op_bytes"])}
            for k, v in module_cost(text).collective_census.items()}


def assert_no_pool_allgather(text: str, pool_bytes: int, n_shards: int = 1,
                             kinds: tuple = ("all-gather",),
                             extra_sizes: tuple = ()) -> dict:
    """Assert the compiled round never all-gathers the pooled feature
    store D_S^f.

    A collective of the pool has one of a small set of exact operand
    sizes: the full pool (``pool_bytes`` — a replicated re-broadcast) or
    one batch-axis shard of it (``pool_bytes / n_shards`` — the operand
    of a GSPMD all-gather re-materializing the pool from its shards,
    the collective the shard-local resample exists to remove).  Checking
    for those exact sizes — rather than a "nothing bigger than the pool
    shard" threshold — keeps the assertion orthogonal to collectives the
    round is SUPPOSED to run: FSDP parameter rehydration gathers are
    weight-shaped, not pool-shaped, and at client-heavy cuts they are
    legitimately larger than a pool shard.  Pass per-step minibatch
    sizes via ``extra_sizes`` to also outlaw per-scan-step row gathers.

    Returns the census on success; raises ``AssertionError`` naming the
    offending kind and size otherwise.
    """
    forbidden = {int(pool_bytes), int(pool_bytes) // max(1, n_shards),
                 *(int(s) for s in extra_sizes)}
    census = collective_census(text)
    for kind in kinds:
        rec = census.get(kind)
        if not rec:
            continue
        hit = forbidden & set(rec["op_bytes"])
        if hit:
            raise AssertionError(
                f"compiled round contains a {kind} moving a pool-sized "
                f"operand ({sorted(hit)} bytes; pool={pool_bytes} over "
                f"{n_shards} shards): the feature store is being "
                f"re-materialized around the shard-local path "
                f"({rec['sites']} sites, {rec['execs']:.0f} execs/round)")
    return census
