"""Pytree helpers used across the framework."""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree: Any) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_bytes(tree: Any) -> int:
    """Total bytes of a pytree (uses each leaf's dtype)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_paths(tree: Any) -> list[str]:
    """Flattened '/'-joined string paths for every leaf."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [path_str(kp) for kp, _ in flat]


def path_str(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def map_with_path(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where fn receives ('a/b/c', leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: fn(path_str(kp), x), tree
    )


def tree_slice(tree: Any, start: int, stop: int | None = None) -> Any:
    """Slice every leaf's leading dim: used to split stacked layer params."""
    return jax.tree.map(lambda x: x[start:stop], tree)


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree: Any, s) -> Any:
    return jax.tree.map(lambda x: x * s, tree)


def tree_l2_norm(tree: Any):
    """Global L2 norm of a pytree of arrays."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def global_norm_and_finite(tree: Any):
    n = tree_l2_norm(tree)
    return n, jnp.isfinite(n)


def human_bytes(n: float) -> str:
    if n <= 0:
        return "0B"
    units = ["B", "KiB", "MiB", "GiB", "TiB"]
    i = min(len(units) - 1, int(math.log(n, 1024)))
    return f"{n / 1024**i:.2f}{units[i]}"


def human_count(n: float) -> str:
    for unit, div in (("T", 1e12), ("B", 1e9), ("M", 1e6), ("K", 1e3)):
        if n >= div:
            return f"{n / div:.2f}{unit}"
    return str(int(n))
