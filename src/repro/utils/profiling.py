"""Host-side round profiling: where a training round's wall time goes.

Two complementary views, both built for the weak-scaling work (the
1-device -> N-device slowdown had to be *located* before it could be
killed):

* :class:`RoundProfiler` — wall time of the HOST sections of
  ``Engine.run()`` (cohort sampling/staging, round dispatch, device
  sync, eval).  Pass one to ``Engine(..., profiler=...)``; the run loop
  wraps its sections and ``summary()`` reports totals, call counts, and
  per-call means.  Zero overhead when no profiler is attached.

* :func:`phase_costs` — wall time of each compiled RoundProgram PHASE.
  Phases fuse into one XLA executable, so they cannot be timed from
  inside a round; instead every program *prefix* (phases[:1],
  phases[:2], …) is compiled and timed as its own round, and the delta
  between consecutive prefixes attributes steady-state time to the
  phase that was appended.  Deltas can go slightly negative when a
  phase lets XLA dead-code-eliminate work a shorter prefix had to
  materialize (Commit often does) — report them as-is, they are real.

* :func:`round_hlo` — the optimized HLO text of the engine's compiled
  monolithic round, for the collective census
  (:func:`repro.utils.hlo_cost.collective_census`) and the
  no-pool-all-gather assertion.
"""
from __future__ import annotations

import time
from collections import defaultdict
from contextlib import contextmanager
from typing import Optional


class RoundProfiler:
    """Accumulates wall time of named host-side sections.

    Sections the Engine instruments: ``sample`` (cohort draw + padding +
    device placement), ``dispatch`` (the async round/extract/tail
    calls), ``sync`` (host blocks on round metrics), ``eval`` (test-set
    evaluation).  ``dispatch`` measuring ms instead of µs is the signal
    that rounds are NOT device-resident (the host is staging or
    blocking inside the dispatch path).
    """

    def __init__(self):
        self.total_s: dict[str, float] = defaultdict(float)
        self.calls: dict[str, int] = defaultdict(int)

    @contextmanager
    def section(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.total_s[name] += time.perf_counter() - t0
            self.calls[name] += 1

    def summary(self) -> dict:
        return {
            name: {
                "total_s": round(total, 6),
                "calls": self.calls[name],
                "mean_ms": round(total / max(1, self.calls[name]) * 1e3, 3),
            }
            for name, total in sorted(self.total_s.items())
        }


@contextmanager
def _borrow_sampler(eng):
    """Run one throwaway cohort draw without perturbing the engine's
    sampling clock or telemetry (both are restored on exit, so a
    profiled engine still replays the exact cohort stream)."""
    clock, ntel = eng._sample_clock, len(eng._telemetry)
    try:
        yield
    finally:
        eng._sample_clock = clock
        del eng._telemetry[ntel:]


def _one_round_args(eng):
    import numpy as np
    rng = np.random.default_rng(eng.cfg.seed + 1)
    state = eng.init_state()
    cohort, xs, ys, mask = eng.sample_round(rng)
    key = eng.round_key(0)
    args = (state, cohort, xs, ys, key)
    return args if mask is None else args + (mask,)


def phase_costs(eng, repeats: int = 5) -> dict:
    """Steady-state per-phase cost of the engine's round, by prefix
    timing.  Returns ``{phase_name: {cum_ms, delta_ms}}`` in program
    order; ``cum_ms`` is the median round time of the prefix ending at
    that phase, ``delta_ms`` the attribution to the phase itself."""
    import jax
    import numpy as np

    from repro.api.phases import RoundProgram, build_algorithm
    from repro.api.registry import get_program
    from repro.optim import adam

    cfg = eng.cfg
    prog = get_program(cfg.algo)
    opt_s, opt_c = adam(cfg.lr_server), adam(cfg.lr_client)
    with _borrow_sampler(eng):
        args = _one_round_args(eng)
    out: dict[str, dict] = {}
    prev = 0.0
    for k in range(1, len(prog.phases) + 1):
        sub = RoundProgram(prog.name, prog.phases[:k],
                           prog.uses_global_client)
        algo = build_algorithm(sub, eng.task, opt_s, opt_c, cfg.cycle,
                               mesh=eng.mesh,
                               state_shardings=eng.state_shardings,
                               shard_data=cfg.shard_cohort)
        jax.block_until_ready(algo.round(*args))       # compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(algo.round(*args))
            ts.append(time.perf_counter() - t0)
        cum = float(np.median(ts)) * 1e3
        name = type(prog.phases[k - 1]).__name__
        while name in out:                             # repeated phase class
            name += "'"
        out[name] = {"cum_ms": round(cum, 3),
                     "delta_ms": round(cum - prev, 3)}
        prev = cum
    return out


def round_hlo(eng, args: Optional[tuple] = None) -> str:
    """Optimized (post-GSPMD) HLO text of the compiled monolithic round
    for the engine's config — the input to the collective census."""
    with _borrow_sampler(eng):
        if args is None:
            args = _one_round_args(eng)
        return eng.algo.round.lower(*args).compile().as_text()
