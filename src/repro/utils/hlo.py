"""HLO-text analysis: collective-traffic accounting for the roofline.

``compiled.cost_analysis()`` reports FLOPs and memory bytes but not
collective traffic, so we parse the (optimized) HLO text and sum operand
sizes for every communication op.  This is the data source for the
"collective term" of the roofline in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

# dtype -> bytes per element
_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

# one shape like bf16[2,4,8] (layout annotations stripped beforehand)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_LAYOUT_RE = re.compile(r"\{[^{}]*\}")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[d0,d1,...]' string."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _all_shapes_bytes(text: str) -> int:
    """Sum of every shape literal appearing in `text` (handles tuples)."""
    return sum(
        _shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(text)
    )


@dataclass
class CollectiveStats:
    """Aggregated collective traffic of one compiled program."""

    bytes_by_kind: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    count_by_kind: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    ops: list[tuple[str, str, int]] = field(default_factory=list)  # (kind, line, bytes)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
            "by_kind": {k: dict(bytes=v, count=self.count_by_kind[k])
                        for k, v in sorted(self.bytes_by_kind.items())},
        }


def collective_stats(hlo_text: str, keep_ops: bool = False) -> CollectiveStats:
    """Parse HLO text; sum operand bytes of every collective op.

    We resolve operand names against a symbol table built from the full
    module so operand (not result) sizes are counted, per the roofline
    definition.  Fusions and `-start`/`-done` async pairs are handled by
    counting the `-start` (or plain) op only.
    """
    # pass 1: symbol table  name -> operand bytes of its defining shape
    sym: dict[str, int] = {}
    lines = hlo_text.splitlines()
    stripped_lines = []
    for ln in lines:
        s = _LAYOUT_RE.sub("", ln)
        stripped_lines.append(s)
        m = _DEF_RE.match(s)
        if m:
            name, rhs = m.groups()
            # shape(s) are everything before the op name; just grab all
            # shape literals in the rhs *before* the first '(' (the result
            # type region).
            head = rhs.split("(", 1)[0]
            b = _all_shapes_bytes(head)
            if b:
                sym[name] = b

    stats = CollectiveStats()
    op_re = re.compile(
        r"\b(" + "|".join(COLLECTIVE_OPS) + r")(-start)?\s*\("
    )
    for s in stripped_lines:
        m = op_re.search(s)
        if m is None:
            continue
        if re.search(r"\b(?:" + "|".join(COLLECTIVE_OPS) + r")-done\b", s):
            continue  # async completion: already counted at -start
        kind = m.group(1)
        # operand list: inside the parens following the op name
        args_str = s[m.end():]
        depth, out = 1, []
        for ch in args_str:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            out.append(ch)
        args_str = "".join(out)
        nbytes = 0
        for arg in args_str.split(","):
            arg = arg.strip().lstrip("%")
            if arg in sym:
                nbytes += sym[arg]
            else:
                # literal shape operand (rare) — count shapes inline
                nbytes += _all_shapes_bytes(arg)
        if nbytes == 0:
            # fall back to the result shape on the lhs
            dm = _DEF_RE.match(s)
            if dm:
                nbytes = _all_shapes_bytes(dm.group(2).split("(", 1)[0])
        stats.bytes_by_kind[kind] += nbytes
        stats.count_by_kind[kind] += 1
        if keep_ops:
            stats.ops.append((kind, s.strip()[:160], nbytes))
    return stats


def flops_and_bytes(cost_analysis: dict) -> tuple[float, float]:
    """Extract (flops, bytes accessed) from compiled.cost_analysis()."""
    if cost_analysis is None:
        return 0.0, 0.0
    flops = float(cost_analysis.get("flops", 0.0))
    b = float(cost_analysis.get("bytes accessed", 0.0))
    return flops, b
