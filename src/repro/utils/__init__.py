from repro.utils import tree
from repro.utils import hlo
