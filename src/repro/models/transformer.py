"""Decoder-only transformer assembly (dense / MoE / SSM / hybrid / VLM).

One module covers all decoder-only assigned archs; whisper's enc-dec
lives in ``encdec.py`` on top of the same block primitives.

Structure
---------
  embed -> [client blocks] -> CUT -> [server blocks] -> final_norm -> head

Blocks are stacked along a leading layer dim and executed with
``lax.scan`` over *groups* of ``period`` blocks (period=2 for gemma2's
local/global alternation, else 1), with ``jax.checkpoint`` on the group
body so backward memory is O(1) in depth.  The split-learning cut is a
leading-dim slice of the stacked block params, so client/server parts
reuse the exact same code path — this is what ``repro.core.split``
relies on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.attention import KVCache, kv_cache_init
from repro.models.layers import (embedding, embedding_init, rmsnorm,
                                 rmsnorm_init, softcap, unembed)
from repro.models.module import stacked_init
from repro.sharding.specs import constrain_batch
from repro.utils.tree import tree_slice

ZERO_METRICS = {"aux_loss": jnp.zeros((), jnp.float32),
                "z_loss": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------- helpers
def block_kind(cfg: ArchConfig) -> str:
    if cfg.family == "ssm":
        return "mamba"
    if cfg.family == "hybrid":
        return "hybrid"
    if cfg.moe is not None:
        return "moe"
    return "dense"


def pattern_period(cfg: ArchConfig) -> int:
    return 2 if cfg.attn.pattern == "local_global" else 1


def _is_local(cfg: ArchConfig, slot: int) -> bool:
    """gemma2 convention: even layer indices are local (sliding window)."""
    return cfg.attn.pattern == "local_global" and slot % 2 == 0


# ------------------------------------------------------------- block init
def _dense_block_init(key, cfg: ArchConfig, dtype):
    ka, kf = jax.random.split(key)
    p = {
        "attn": attn_lib.attn_init(ka, cfg, dtype),
        "ffn": ffn_lib.swiglu_init(kf, cfg.d_model, cfg.d_ff, dtype),
        "norm_attn": rmsnorm_init(cfg.d_model, dtype),
        "norm_ffn": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.sandwich_norm:
        p["post_attn"] = rmsnorm_init(cfg.d_model, dtype)
        p["post_ffn"] = rmsnorm_init(cfg.d_model, dtype)
    return p


def _moe_block_init(key, cfg: ArchConfig, dtype):
    ka, km, ks = jax.random.split(key, 3)
    p = {
        "attn": attn_lib.attn_init(ka, cfg, dtype),
        "moe": moe_lib.moe_init(km, cfg.d_model, cfg.moe, dtype),
        "norm_attn": rmsnorm_init(cfg.d_model, dtype),
        "norm_ffn": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.moe.n_shared_experts:
        f = cfg.moe.n_shared_experts * cfg.moe.d_ff_expert
        p["shared_ffn"] = ffn_lib.swiglu_init(ks, cfg.d_model, f, dtype)
    return p


def _mamba_block_init(key, cfg: ArchConfig, dtype):
    km = jax.random.split(key, 2)[0]
    return {
        "mamba": mamba_lib.mamba_init(km, cfg, dtype),
        "norm": rmsnorm_init(cfg.d_model, dtype),
    }


def block_init(key, cfg: ArchConfig, dtype):
    kind = block_kind(cfg)
    if kind in ("mamba", "hybrid"):
        return _mamba_block_init(key, cfg, dtype)
    if kind == "moe":
        return _moe_block_init(key, cfg, dtype)
    return _dense_block_init(key, cfg, dtype)


# ---------------------------------------------------------- block forward
def dense_or_moe_block(params, cfg: ArchConfig, x, positions, window):
    """One attention block (full-seq).  Returns (x, metrics)."""
    h = rmsnorm(params["norm_attn"], x, cfg.norm_eps)
    a, _ = attn_lib.attend_full(params["attn"], cfg, h, positions, window)
    if cfg.sandwich_norm:
        a = rmsnorm(params["post_attn"], a, cfg.norm_eps)
    x = x + a
    h = rmsnorm(params["norm_ffn"], x, cfg.norm_eps)
    metrics = ZERO_METRICS
    if "moe" in params:
        f, m = moe_lib.moe_apply(params["moe"], cfg.moe, h,
                                 expert_spec=moe_lib.expert_partition_spec(cfg.moe))
        if "shared_ffn" in params:
            f = f + ffn_lib.swiglu(params["shared_ffn"], h)
        metrics = {"aux_loss": m["aux_loss"], "z_loss": m["z_loss"]}
    else:
        f = ffn_lib.swiglu(params["ffn"], h)
        if cfg.sandwich_norm:
            f = rmsnorm(params["post_ffn"], f, cfg.norm_eps)
    return x + f, metrics


def mamba_block(params, cfg: ArchConfig, x):
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    y, _ = mamba_lib.mamba_forward(params["mamba"], cfg, h)
    return x + y, ZERO_METRICS


# --------------------------------------------------------------- the model
class Transformer:
    """Namespace of pure functions for decoder-only models."""

    # ---------------- init ----------------
    @staticmethod
    def init(key, cfg: ArchConfig):
        dtype = cfg.jnp_dtype
        ke, kb, kh, ks = jax.random.split(key, 4)
        kind = block_kind(cfg)
        n = cfg.n_layers
        params = {
            "embed": embedding_init(ke, cfg.vocab_padded, cfg.d_model, dtype),
            "blocks": stacked_init(
                lambda k: block_init(k, cfg, dtype), kb, n),
            "final_norm": rmsnorm_init(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded),
                                       jnp.float32).astype(dtype) * 0.02}
        if kind == "hybrid":
            # one SHARED attention block (zamba2), reused at each position
            shared_cfg = cfg
            params["shared_attn"] = _dense_block_init(ks, shared_cfg, dtype)
        return params

    # -------------- stacks -----------------
    @staticmethod
    def _run_stack(blocks, cfg: ArchConfig, x, positions, *, layer_offset: int,
                   long_context: bool, shared_attn=None, n_blocks: int = None):
        """Scan over stacked block params.  Returns (x, metrics_sum)."""
        kind = block_kind(cfg)
        period = pattern_period(cfg)
        n = n_blocks if n_blocks is not None else \
            jax.tree.leaves(blocks)[0].shape[0]
        if n == 0:
            return x, ZERO_METRICS
        assert n % period == 0, f"stack of {n} not divisible by period {period}"
        grouped = jax.tree.map(
            lambda a: a.reshape((n // period, period) + a.shape[1:]), blocks)

        def group_body(carry, gparams):
            xs, acc = carry
            xs = constrain_batch(xs)    # keep batch on the data axes
            m_tot = acc
            for slot in range(period):
                bp = jax.tree.map(lambda a: a[slot], gparams)
                if kind in ("mamba", "hybrid"):
                    xs, m = mamba_block(bp, cfg, xs)
                else:
                    local = _is_local(cfg, (layer_offset + slot) % period
                                      if period > 1 else 0)
                    window = attn_lib.layer_window(cfg, local, long_context)
                    xs, m = dense_or_moe_block(bp, cfg, xs, positions, window)
                m_tot = {k: m_tot[k] + m[k] for k in m_tot}
            return (xs, m_tot), None

        body = jax.checkpoint(group_body)
        (x, metrics), _ = jax.lax.scan(body, (x, ZERO_METRICS), grouped)
        return x, metrics

    @staticmethod
    def _hybrid_stack(blocks, shared_attn, cfg: ArchConfig, x, positions, *,
                      first_block: int, n_blocks: int, long_context: bool):
        """Mamba blocks [first, first+n) with the shared attention block
        applied after every block index listed in cfg.ssm.shared_attn_positions."""
        pos_set = [p for p in cfg.ssm.shared_attn_positions
                   if first_block <= p < first_block + n_blocks]
        window = attn_lib.layer_window(cfg, False, long_context)
        metrics = ZERO_METRICS
        cursor = first_block
        segments = []
        for p in pos_set:
            segments.append((cursor, p + 1, True))
            cursor = p + 1
        if cursor < first_block + n_blocks:
            segments.append((cursor, first_block + n_blocks, False))
        for (a, b, with_attn) in segments:
            seg = tree_slice(blocks, a - first_block, b - first_block)
            x, m = Transformer._run_stack(seg, cfg, x, positions,
                                          layer_offset=a, long_context=long_context)
            metrics = {k: metrics[k] + m[k] for k in metrics}
            if with_attn:
                x, m = dense_or_moe_block(shared_attn, cfg, x, positions, window)
                metrics = {k: metrics[k] + m[k] for k in metrics}
        return x, metrics

    # -------------- forward -----------------
    @staticmethod
    def embed_inputs(params, cfg: ArchConfig, tokens, patch_embeds=None):
        x = embedding(params["embed"], tokens)
        if cfg.family == "vlm" and patch_embeds is not None:
            npt = patch_embeds.shape[1]
            x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, npt:]], axis=1)
        return constrain_batch(x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype))

    @staticmethod
    def stack_forward(params, cfg: ArchConfig, x, positions, *,
                      first_block: int, n_blocks: int, long_context: bool = False):
        """Run blocks [first, first+n) of a (possibly sliced) stack."""
        if n_blocks == 0:
            return x, ZERO_METRICS
        if block_kind(cfg) == "hybrid":
            shared = params.get("shared_attn")
            if shared is None:
                # split-client stacks must not span a shared-attn position
                assert not any(first_block <= p < first_block + n_blocks
                               for p in cfg.ssm.shared_attn_positions), \
                    "client cut crosses a shared-attention position"
            return Transformer._hybrid_stack(
                params["blocks"], shared, cfg, x, positions,
                first_block=first_block, n_blocks=n_blocks,
                long_context=long_context)
        return Transformer._run_stack(
            params["blocks"], cfg, x, positions, layer_offset=first_block,
            long_context=long_context)

    @staticmethod
    def head(params, cfg: ArchConfig, x, keep_padded: bool = False):
        """Final norm + unembedding.  Returns fp32 logits [..., vocab]
        (padded columns sliced off unless ``keep_padded``)."""
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x) if cfg.tie_embeddings \
            else x @ params["lm_head"]["w"]
        logits = softcap(logits.astype(jnp.float32), cfg.attn.final_softcap)
        if keep_padded or cfg.vocab_padded == cfg.vocab:
            return logits
        return logits[..., :cfg.vocab]

    @staticmethod
    def chunked_lm_loss(params, cfg: ArchConfig, hidden, labels,
                        chunk: int = 512):
        """Cross-entropy from final hidden states without materializing the
        [S, vocab] logits (perf iteration 4, EXPERIMENTS.md §Perf): the
        sequence is processed in checkpointed chunks, each computing a
        [chunk, vocab_padded] logits tile (vocab stays model-sharded).
        Padded vocab columns are masked to -inf.  Returns (mean nll,
        mean accuracy)."""
        B, S, d = hidden.shape
        chunk = min(chunk, S)
        if S % chunk:
            pad = chunk - S % chunk
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
            S += pad
        nc = S // chunk
        hs = jnp.moveaxis(hidden.reshape(B, nc, chunk, d), 1, 0)
        ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
        n_pad = cfg.vocab_padded - cfg.vocab

        @jax.checkpoint
        def one(args):
            h, l = args
            logits = Transformer.head(params, cfg, h, keep_padded=True)
            if n_pad:
                logits = logits.at[..., cfg.vocab:].set(-1e30)
            ll = jax.nn.log_softmax(logits, axis=-1)
            valid = (l >= 0).astype(jnp.float32)
            lc = jnp.clip(l, 0)
            nll = -jnp.take_along_axis(ll, lc[..., None], axis=-1)[..., 0]
            correct = (jnp.argmax(ll, -1) == lc).astype(jnp.float32)
            return (jnp.sum(nll * valid), jnp.sum(correct * valid),
                    jnp.sum(valid))

        nlls, corrects, counts = jax.lax.map(one, (hs, ls))
        n = jnp.maximum(jnp.sum(counts), 1.0)
        return jnp.sum(nlls) / n, jnp.sum(corrects) / n

    @staticmethod
    def forward(params, cfg: ArchConfig, tokens, patch_embeds=None,
                long_context: bool = False):
        """Full forward.  tokens [B,S] -> (logits fp32 [B,S,V], metrics)."""
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = Transformer.embed_inputs(params, cfg, tokens, patch_embeds)
        x, metrics = Transformer.stack_forward(
            params, cfg, x, positions, first_block=0, n_blocks=cfg.n_layers,
            long_context=long_context)
        return Transformer.head(params, cfg, x), metrics

    # -------------- loss -----------------
    @staticmethod
    def loss_fn(params, cfg: ArchConfig, tokens, labels, patch_embeds=None):
        logits, metrics = Transformer.forward(params, cfg, tokens, patch_embeds)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
        if cfg.moe is not None:
            loss = (loss + cfg.moe.aux_weight * metrics["aux_loss"]
                    + cfg.moe.router_z_weight * metrics["z_loss"])
        return loss, metrics

    # -------------- serving -----------------
    @staticmethod
    def cache_capacity(cfg: ArchConfig, seq_len: int, long_context: bool):
        if long_context:
            w = cfg.long_context_window
            if cfg.attn.pattern in ("local", "local_global") and cfg.attn.window:
                w = max(w, cfg.attn.window)
            return min(seq_len, w)
        return seq_len

    @staticmethod
    def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int,
                          long_context: bool = False):
        """Allocate KV caches / SSM state for decode at a given context."""
        dtype = cfg.jnp_dtype
        kind = block_kind(cfg)
        state = {}
        if kind == "mamba":
            state["mamba"] = mamba_lib.mamba_state_init(cfg, cfg.n_layers, batch, dtype)
        elif kind == "hybrid":
            state["mamba"] = mamba_lib.mamba_state_init(cfg, cfg.n_layers, batch, dtype)
            n_apps = len(cfg.ssm.shared_attn_positions)
            cap = Transformer.cache_capacity(cfg, seq_len, long_context)
            state["kv"] = kv_cache_init(cfg, n_apps, batch, cap, dtype)
        else:
            cap = Transformer.cache_capacity(cfg, seq_len, long_context)
            state["kv"] = kv_cache_init(cfg, cfg.n_layers, batch, cap, dtype)
        state["pos"] = jnp.zeros((), jnp.int32)
        return state

    @staticmethod
    def decode_step(params, cfg: ArchConfig, token, state,
                    long_context: bool = False):
        """One-token decode.  token [B,1] -> (logits [B,1,V], state')."""
        pos = state["pos"]
        x = Transformer.embed_inputs(params, cfg, token)
        kind = block_kind(cfg)

        if kind == "mamba":
            ms: mamba_lib.MambaState = state["mamba"]

            def body(carry, inp):
                xs = carry
                bp, h, cv = inp
                hnorm = rmsnorm(bp["norm"], xs[:, 0], cfg.norm_eps)[:, None]
                y, h2, cv2 = mamba_lib.mamba_decode(bp["mamba"], cfg, hnorm, h, cv)
                return xs + y, (h2, cv2)

            xs, (h_new, cv_new) = jax.lax.scan(
                body, x, (params["blocks"], ms.h, ms.conv))
            state = dict(state, mamba=mamba_lib.MambaState(h_new, cv_new),
                         pos=pos + 1)
            return Transformer.head(params, cfg, xs), state

        if kind == "hybrid":
            return Transformer._hybrid_decode(params, cfg, x, state, long_context)

        # dense / moe / vlm: scan over layers; the cache is CARRIED as one
        # buffer and updated in place per layer (ys-collection would
        # double-buffer the whole cache — §Perf decode iteration).
        kv: KVCache = state["kv"]
        period = pattern_period(cfg)

        def body(carry, inp):
            xs, k_all, v_all = carry
            bp, li = inp
            lk = jax.lax.dynamic_index_in_dim(k_all, li, 0, keepdims=False)
            lv = jax.lax.dynamic_index_in_dim(v_all, li, 0, keepdims=False)
            h = rmsnorm(bp["norm_attn"], xs, cfg.norm_eps)
            # per-layer local/global needs a traced switch when period>1
            if period > 1:
                w_local = cfg.attn.window
                w_global = attn_lib.layer_window(cfg, False, long_context)
                a_l, k_l, v_l = attn_lib.attend_decode(
                    bp["attn"], cfg, h, lk, lv, pos, w_local)
                a_g, k_g, v_g = attn_lib.attend_decode(
                    bp["attn"], cfg, h, lk, lv, pos, w_global)
                is_local = (li % 2 == 0)
                a = jnp.where(is_local, a_l, a_g)
                nk = jnp.where(is_local, k_l, k_g)
                nv = jnp.where(is_local, v_l, v_g)
            else:
                window = attn_lib.layer_window(
                    cfg, cfg.attn.pattern == "local", long_context)
                a, nk, nv = attn_lib.attend_decode(
                    bp["attn"], cfg, h, lk, lv, pos, window)
            if cfg.sandwich_norm:
                a = rmsnorm(bp["post_attn"], a, cfg.norm_eps)
            xs = xs + a
            h = rmsnorm(bp["norm_ffn"], xs, cfg.norm_eps)
            if "moe" in bp:
                f, _ = moe_lib.moe_apply(bp["moe"], cfg.moe, h)
                if "shared_ffn" in bp:
                    f = f + ffn_lib.swiglu(bp["shared_ffn"], h)
            else:
                f = ffn_lib.swiglu(bp["ffn"], h)
                if cfg.sandwich_norm:
                    f = rmsnorm(bp["post_ffn"], f, cfg.norm_eps)
            k_all = jax.lax.dynamic_update_index_in_dim(k_all, nk, li, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(v_all, nv, li, 0)
            return (xs + f, k_all, v_all), None

        lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        (xs, nk, nv), _ = jax.lax.scan(
            body, (x, kv.k, kv.v), (params["blocks"], lidx))
        state = dict(state, kv=KVCache(nk, nv, kv.idx + 1), pos=pos + 1)
        return Transformer.head(params, cfg, xs), state

    @staticmethod
    def _hybrid_decode(params, cfg: ArchConfig, x, state, long_context):
        pos = state["pos"]
        ms: mamba_lib.MambaState = state["mamba"]
        kv: KVCache = state["kv"]
        window = attn_lib.layer_window(cfg, False, long_context)
        attn_pos = cfg.ssm.shared_attn_positions
        h_all, cv_all = ms.h, ms.conv
        nk, nv = kv.k, kv.v
        xs = x
        cursor = 0
        for app_i, p in enumerate(list(attn_pos) + [cfg.n_layers - 1]):
            is_attn = app_i < len(attn_pos)
            hi = p + 1 if is_attn else cfg.n_layers
            if hi > cursor:
                seg_blocks = tree_slice(params["blocks"], cursor, hi)
                seg_h = h_all[cursor:hi]
                seg_cv = cv_all[cursor:hi]

                def body(carry, inp):
                    xc = carry
                    bp, h, cv = inp
                    hnorm = rmsnorm(bp["norm"], xc[:, 0], cfg.norm_eps)[:, None]
                    y, h2, cv2 = mamba_lib.mamba_decode(bp["mamba"], cfg, hnorm, h, cv)
                    return xc + y, (h2, cv2)

                xs, (h2, cv2) = jax.lax.scan(body, xs, (seg_blocks, seg_h, seg_cv))
                h_all = h_all.at[cursor:hi].set(h2)
                cv_all = cv_all.at[cursor:hi].set(cv2)
                cursor = hi
            if is_attn:
                bp = params["shared_attn"]
                h = rmsnorm(bp["norm_attn"], xs, cfg.norm_eps)
                a, k2, v2 = attn_lib.attend_decode(
                    bp["attn"], cfg, h, nk[app_i], nv[app_i], pos, window)
                nk = nk.at[app_i].set(k2)
                nv = nv.at[app_i].set(v2)
                xs = xs + a
                h = rmsnorm(bp["norm_ffn"], xs, cfg.norm_eps)
                xs = xs + ffn_lib.swiglu(bp["ffn"], h)
        state = dict(state,
                     mamba=mamba_lib.MambaState(h_all, cv_all),
                     kv=KVCache(nk, nv, kv.idx + 1), pos=pos + 1)
        return Transformer.head(params, cfg, xs), state
