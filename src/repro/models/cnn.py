"""The paper's own model zoo: LEAF-style CNNs + ResNet9 + gaze MLP head.

These are the models CycleSL was benchmarked with (paper §4.1, App. H).
Each model is expressed as an ordered list of *stages*; the split-learning
cut index selects how many stages stay on the client — exactly the
paper's block-wise cut ablation (Table 4).

Conv layers use NHWC and ``lax.conv_general_dilated``; everything is
float32 and CPU-friendly (the paper-claims benchmarks run for real).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.models import module


# --------------------------------------------------------------- conv ops
def conv_init(key, kh: int, kw: int, cin: int, cout: int, dtype=jnp.float32):
    fan_in = kh * kw * cin
    w = jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout), jnp.float32)
    return {"w": (w / jnp.sqrt(fan_in)).astype(dtype),
            "b": jnp.zeros((cout,), dtype)}


def conv2d(params, x, stride: int = 1, padding: str = "SAME"):
    y = jax.lax.conv_general_dilated(
        x, params["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + params["b"]


def maxpool(x, k: int = 2, s: int = 2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), "VALID")


def batchnorm_init(c: int):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def batchnorm(params, x, eps: float = 1e-5):
    # batch-stat norm (training mode); SL benchmarks always train
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]


# ------------------------------------------------------- stage-list models
class StageModel:
    """A model = ordered stages; stage i: (init_fn(key)->params, apply_fn).

    ``cut`` splits stages into client [0:cut] / server [cut:] — the
    paper's block-wise cut point.
    """

    def __init__(self, name: str, stages: Sequence[tuple[Callable, Callable]],
                 n_classes: int, head_is_linear: bool = False):
        self.name = name
        self.stages = list(stages)
        self.n_classes = n_classes
        self.n_stages = len(stages)
        # True iff the FINAL stage is a bias-free flatten-matmul
        # (``x.reshape(B, -1) @ w``): the contract that lets a last-cut
        # split expose the head to the fused gather+loss kernel
        # (SplitTask.server_head).  resnet9's head pools first, so it
        # does NOT qualify.
        self.head_is_linear = head_is_linear

    def init(self, key):
        keys = jax.random.split(key, self.n_stages)
        return [init(k) for (init, _), k in zip(self.stages, keys)]

    def apply_range(self, params, x, lo: int, hi: int):
        for i in range(lo, hi):
            x = self.stages[i][1](params[i], x)
        return x

    def apply(self, params, x):
        return self.apply_range(params, x, 0, self.n_stages)


# ------------------------------------------------------------ LEAF FEMNIST
def femnist_cnn(n_classes: int = 62, width: int = 32) -> StageModel:
    """LEAF FEMNIST CNN (paper Table 11).  Input [B, 28, 28, 1].
    Cut in the middle (stage 2 of 4) matches the paper's setup."""
    w = width

    def s0_init(k):
        return {"conv": conv_init(k, 5, 5, 1, w)}

    def s0(p, x):
        return maxpool(jax.nn.relu(conv2d(p["conv"], x)))

    def s1_init(k):
        return {"conv": conv_init(k, 5, 5, w, 2 * w)}

    def s1(p, x):
        return maxpool(jax.nn.relu(conv2d(p["conv"], x)))

    def s2_init(k):
        return {"lin": {"w": module.dense_init(k, 7 * 7 * 2 * w, 2048)}}

    def s2(p, x):
        x = x.reshape(x.shape[0], -1)
        return jax.nn.relu(x @ p["lin"]["w"])

    def s3_init(k):
        return {"lin": {"w": module.dense_init(k, 2048, n_classes)}}

    def s3(p, x):
        return x @ p["lin"]["w"]

    return StageModel("femnist_cnn", [(s0_init, s0), (s1_init, s1),
                                      (s2_init, s2), (s3_init, s3)], n_classes,
                      head_is_linear=True)


# ------------------------------------------------------------- LEAF CelebA
def celeba_cnn(n_classes: int = 2, width: int = 32, img: int = 84) -> StageModel:
    """LEAF CelebA CNN (paper Table 13): 4 conv-bn-pool stages + head.
    Input [B, img, img, 3]; cut after stage 1 (paper: middle)."""
    w = width

    def conv_stage_init(cin, cout):
        def init(k):
            return {"conv": conv_init(k, 3, 3, cin, cout),
                    "bn": batchnorm_init(cout)}
        return init

    def conv_stage(p, x):
        x = conv2d(p["conv"], x)
        x = batchnorm(p["bn"], x)
        return jax.nn.relu(maxpool(x))

    final_hw = img // 16

    def head_init(k):
        return {"lin": {"w": module.dense_init(k, final_hw * final_hw * w,
                                               n_classes)}}

    def head(p, x):
        return x.reshape(x.shape[0], -1) @ p["lin"]["w"]

    stages = [(conv_stage_init(3, w), conv_stage)]
    for _ in range(3):
        stages.append((conv_stage_init(w, w), conv_stage))
    stages.append((head_init, head))
    return StageModel("celeba_cnn", stages, n_classes,
                      head_is_linear=True)


# ----------------------------------------------------------------- ResNet9
def resnet9(n_classes: int = 100, width: int = 64, img: int = 32) -> StageModel:
    """ResNet9 (paper Table 4 ablation: 4 conv blocks, 2 residual blocks,
    1 head = 6 cut positions).  Input [B, img, img, 3]."""
    w = width

    def convblock_init(cin, cout):
        def init(k):
            return {"conv": conv_init(k, 3, 3, cin, cout),
                    "bn": batchnorm_init(cout)}
        return init

    def convblock(p, x, pool):
        x = jax.nn.relu(batchnorm(p["bn"], conv2d(p["conv"], x)))
        return maxpool(x) if pool else x

    def resblock_init(c):
        def init(k):
            k1, k2 = jax.random.split(k)
            return {"c1": conv_init(k1, 3, 3, c, c), "b1": batchnorm_init(c),
                    "c2": conv_init(k2, 3, 3, c, c), "b2": batchnorm_init(c)}
        return init

    def resblock(p, x):
        h = jax.nn.relu(batchnorm(p["b1"], conv2d(p["c1"], x)))
        h = jax.nn.relu(batchnorm(p["b2"], conv2d(p["c2"], h)))
        return x + h

    def head_init(k):
        return {"lin": {"w": module.dense_init(k, 8 * w, n_classes)}}

    def head(p, x):
        x = jnp.max(x, axis=(1, 2))         # global max pool
        return x @ p["lin"]["w"]

    stages = [
        (convblock_init(3, w), partial(_flip(convblock), False)),         # conv1
        (convblock_init(w, 2 * w), partial(_flip(convblock), True)),      # conv2
        (resblock_init(2 * w), resblock),                                 # res1
        (convblock_init(2 * w, 4 * w), partial(_flip(convblock), True)),  # conv3
        (convblock_init(4 * w, 8 * w), partial(_flip(convblock), True)),  # conv4
        (resblock_init(8 * w), resblock),                                 # res2
        (head_init, head),                                                # head
    ]
    return StageModel("resnet9", stages, n_classes)


def _flip(fn):
    """(p, x, flag) -> (flag, p, x) so partial can bind the static flag."""
    return lambda flag, p, x: fn(p, x, flag)


# -------------------------------------------------------------------- MLP
def mlp(d_in: int, hidden: Sequence[int], d_out: int) -> StageModel:
    """Generic MLP (gaze-estimator head analog / quick tasks)."""
    dims = [d_in] + list(hidden)

    def lin_init(a, b):
        def init(k):
            return {"w": module.dense_init(k, a, b)}
        return init

    def lin(act, p, x):
        y = x.reshape(x.shape[0], -1) @ p["w"]
        return jax.nn.relu(y) if act else y

    stages = [(lin_init(a, b), partial(lin, True))
              for a, b in zip(dims[:-1], dims[1:])]
    stages.append((lin_init(dims[-1], d_out), partial(lin, False)))
    return StageModel("mlp", stages, d_out, head_is_linear=True)
