"""Pure-JAX model substrate.

Every model in this package follows the same functional contract:

  params = <model>.init(key, cfg)          # pytree of jnp arrays
  out    = <model>.apply(params, cfg, *x)  # pure function

Layer stacks are *stacked* along a leading ``layers`` dim and executed
with ``jax.lax.scan`` so HLO size (and compile time) is O(1) in depth —
a hard requirement for the 512-virtual-device multi-pod dry-run.
"""
