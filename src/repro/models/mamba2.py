"""Mamba-2 block: SSD (state-space duality) with chunked scan.

Reference: *Transformers are SSMs* (arXiv:2405.21060).  The chunked SSD
computation here is the pure-jnp oracle for the Pallas ``ssd_scan``
kernel; the block wrapper (projections, depthwise causal conv, gating)
is shared by the pure-SSM (mamba2-2.7b) and hybrid (zamba2) archs.

Layout: x [B, L, H, P] (heads x head_dim), B/C [B, L, G, N] (groups x
state), dt [B, L, H], A [H] negative reals.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models import module
from repro.models.layers import rmsnorm, rmsnorm_init


# ---------------------------------------------------------------- SSD core
def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.  Returns y [B, L, H, P] and final state
    h [B, H, N, P].  Pure-jnp; serves as the Pallas kernel oracle.

    Chunks are processed with a *sequential* checkpointed ``lax.scan``
    (perf iteration 2, EXPERIMENTS.md §Perf): only one chunk's [Q,Q,H]
    decay/score tensors are live at a time, so peak temp is
    O(B·Q²·H) instead of O(B·L/Q·Q²·H) = O(B·L·Q·H).
    """
    Bsz, L, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0, f"L={L} not divisible by chunk={chunk}"
    nc, Q = L // chunk, chunk
    rep = H // G
    Af = A.astype(jnp.float32)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]

    # [nc, B, Q, ...] chunk-major layouts for the scan
    def cm_(a, tail):
        return jnp.moveaxis(a.reshape((Bsz, nc, Q) + tail), 1, 0)

    xf = cm_(x.astype(jnp.float32), (H, Pd))
    dtf = cm_(dt.astype(jnp.float32), (H,))
    Bf = cm_(Bm.astype(jnp.float32), (G, N))
    Cf = cm_(Cm.astype(jnp.float32), (G, N))

    @jax.checkpoint
    def step(h, inp):
        xc, dtc, bc, cc = inp                      # [B,Q,H,P],[B,Q,H],[B,Q,G,N]x2
        bc = jnp.repeat(bc, rep, axis=2)           # [B,Q,H,N]
        cc = jnp.repeat(cc, rep, axis=2)
        dA = dtc * Af                              # [B,Q,H] (<= 0)
        cum = jnp.cumsum(dA, axis=1)
        # intra-chunk dual form (double-where keeps backward NaN-free)
        diff = cum[:, :, None, :] - cum[:, None, :, :]       # [B,Qi,Qj,H]
        lmat = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
        w = jnp.einsum("bqhn,bkhn->bqkh", cc, bc) * lmat * dtc[:, None, :, :]
        y = jnp.einsum("bqkh,bkhp->bqhp", w, xc)
        # inter-chunk contribution from the carried state
        y = y + jnp.einsum("bqhn,bhnp->bqhp", cc * jnp.exp(cum)[..., None], h)
        # state update
        last = cum[:, -1:, :]                                # [B,1,H]
        seg = jnp.exp(last - cum)
        h = (jnp.exp(last[:, 0])[:, :, None, None] * h
             + jnp.einsum("bqhn,bqh,bqhp->bhnp", bc, dtc * seg, xc))
        return h, y

    h0 = jnp.zeros((Bsz, H, N, Pd), jnp.float32)
    h_final, ys = jax.lax.scan(step, h0, (xf, dtf, Bf, Cf))  # ys [nc,B,Q,H,P]
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, L, H, Pd)
    return y.astype(x.dtype), h_final


def ssd_decode_step(h, x, dt, A, Bm, Cm):
    """Single-token SSD update.  h [B,H,N,P]; x [B,H,P]; dt [B,H];
    B/C [B,G,N].  Returns (y [B,H,P], h')."""
    G = Bm.shape[1]
    rep = x.shape[1] // G
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)      # [B,H,N]
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))                 # [B,H]
    dBx = jnp.einsum("bh,bhn,bhp->bhnp", dtf, Bf, x.astype(jnp.float32))
    h = dA[:, :, None, None] * h + dBx
    y = jnp.einsum("bhn,bhnp->bhp", Cf, h)
    return y.astype(x.dtype), h


# ------------------------------------------------------------- block level
class MambaState(NamedTuple):
    """Decode-time recurrent state for a stack of mamba blocks.
    h: [L, B, H, N, P]; conv: [L, B, d_conv-1, conv_ch]."""
    h: jax.Array
    conv: jax.Array


def _conv_channels(cfg: ArchConfig) -> int:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    return d_inner + 2 * s.n_groups * s.d_state


def mamba_init(key, cfg: ArchConfig, dtype):
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    conv_ch = _conv_channels(cfg)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + H
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": module.dense_init(k1, d, d_in_proj, dtype),
        "conv_w": (jax.random.normal(k2, (s.d_conv, conv_ch), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": rmsnorm_init(d_inner, dtype),
        "w_out": module.dense_init(k3, d_inner, d, dtype),
    }


def _split_in_proj(cfg: ArchConfig, zxbcdt):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    gN = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + d_inner + 2 * gN], axis=-1)
    return z, xbc, dt, d_inner, H, gN


def _causal_conv(w, b, xbc):
    """Depthwise causal conv over time.  xbc [B, L, C]; w [K, C]."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(y + b)


def mamba_forward(params, cfg: ArchConfig, x):
    """Full-sequence forward of one mamba2 block.  x [B, L, d]."""
    s = cfg.ssm
    zxbcdt = x @ params["w_in"]
    z, xbc, dt, d_inner, H, gN = _split_in_proj(cfg, zxbcdt)
    xbc = _causal_conv(params["conv_w"], params["conv_b"], xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + gN], axis=-1)
    Bsz, L = x.shape[0], x.shape[1]
    xs = xs.reshape(Bsz, L, H, s.head_dim)
    Bm = Bm.reshape(Bsz, L, s.n_groups, s.d_state)
    Cm = Cm.reshape(Bsz, L, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    chunk = min(s.chunk, L)
    y, h = ssd_chunked(xs, dt, A, Bm, Cm, chunk)
    y = y + xs * params["D"][:, None].astype(xs.dtype)
    y = y.reshape(Bsz, L, d_inner)
    y = rmsnorm(params["gate_norm"], y) * jax.nn.silu(z)
    return y @ params["w_out"], h


def mamba_decode(params, cfg: ArchConfig, x, h, conv_state):
    """One-token decode.  x [B, 1, d]; h [B,H,N,P]; conv_state
    [B, d_conv-1, conv_ch].  Returns (y [B,1,d], h', conv_state')."""
    s = cfg.ssm
    zxbcdt = x[:, 0] @ params["w_in"]                      # [B, d_in_proj]
    z, xbc, dt, d_inner, H, gN = _split_in_proj(cfg, zxbcdt)
    # conv over [conv_state; xbc]
    w, b = params["conv_w"], params["conv_b"]
    K = w.shape[0]
    full = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)   # [B,K,C]
    y_conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", full, w) + b)
    conv_state = full[:, 1:]
    xs, Bm, Cm = jnp.split(y_conv, [d_inner, d_inner + gN], axis=-1)
    Bsz = x.shape[0]
    xs = xs.reshape(Bsz, H, s.head_dim)
    Bm = Bm.reshape(Bsz, s.n_groups, s.d_state)
    Cm = Cm.reshape(Bsz, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    y, h = ssd_decode_step(h, xs, dt, A, Bm, Cm)
    y = y + xs * params["D"][:, None].astype(xs.dtype)
    y = y.reshape(Bsz, d_inner)
    y = rmsnorm(params["gate_norm"], y) * jax.nn.silu(z)
    return (y @ params["w_out"])[:, None, :], h, conv_state


def mamba_state_init(cfg: ArchConfig, n_blocks: int, batch: int, dtype):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return MambaState(
        h=jnp.zeros((n_blocks, batch, H, s.d_state, s.head_dim), jnp.float32),
        conv=jnp.zeros((n_blocks, batch, s.d_conv - 1, _conv_channels(cfg)), dtype),
    )
