"""Dense feed-forward blocks: SwiGLU (default) and GeLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module


def swiglu_init(key, d: int, f: int, dtype):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": module.dense_init(kg, d, f, dtype),
        "w_up": module.dense_init(ku, d, f, dtype),
        "w_down": module.dense_init(kd, f, d, dtype),
    }


def swiglu(params, x):
    g = jax.nn.silu(x @ params["w_gate"])
    return (g * (x @ params["w_up"])) @ params["w_down"]


def gelu_mlp_init(key, d: int, f: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": module.dense_init(k1, d, f, dtype),
        "b_in": jnp.zeros((f,), dtype),
        "w_out": module.dense_init(k2, f, d, dtype),
        "b_out": jnp.zeros((d,), dtype),
    }


def gelu_mlp(params, x):
    h = jax.nn.gelu(x @ params["w_in"] + params["b_in"])
    return h @ params["w_out"] + params["b_out"]
