"""Grouped-query attention with RoPE, logit softcap, sliding windows, and a
ring-buffer KV cache for decode.

All shapes are batch-first: x [B, S, D].  Heads layout [B, S, H, Dh].
The XLA einsum path here is also the correctness oracle for the Pallas
flash-attention kernel in ``repro.kernels``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, AttnConfig
from repro.models import module
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_init, softcap

NEG_INF = -2.0e38


def attn_init(key, cfg: ArchConfig, dtype):
    """QKV + output projections (no biases, per the assigned archs)."""
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": module.dense_init(kq, d, cfg.n_heads * hd, dtype),
        "wk": module.dense_init(kk, d, cfg.n_kv_heads * hd, dtype),
        "wv": module.dense_init(kv, d, cfg.n_kv_heads * hd, dtype),
        "wo": module.dense_init(ko, cfg.n_heads * hd, d, dtype),
    }
    if cfg.attn.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _merge_heads(x):
    return x.reshape(x.shape[:-2] + (-1,))


def _mask_bias(q_pos, k_pos, window: Optional[int], causal: bool = True):
    """Additive mask bias [..., Sq, Sk] built from absolute positions."""
    delta = q_pos[..., :, None] - k_pos[..., None, :]
    valid = jnp.ones(delta.shape, bool)
    if causal:
        valid &= delta >= 0
    if window is not None:
        valid &= delta < window
    return jnp.where(valid, 0.0, NEG_INF)


def sdpa(q, k, v, bias, cap: Optional[float] = None):
    """q [B,Sq,H,Dh], k/v [B,Sk,Hkv,Dh] (GQA broadcast), bias [B?,Sq,Sk]."""
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qf = q.astype(jnp.float32) / jnp.sqrt(Dh).astype(jnp.float32)
    qf = qf.reshape(B, Sq, Hkv, g, Dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    logits = softcap(logits, cap)
    logits = logits + bias[:, None, None] if bias.ndim == 3 else logits + bias
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


# Above this many query positions, attention runs q-chunked (blockwise)
# so the S x S score tensor is never materialized — perf iteration 1,
# see EXPERIMENTS.md §Perf.  Chunks are checkpointed: backward
# recomputes per-chunk scores (flash-style memory at XLA level).
QCHUNK_THRESHOLD = 2048
QCHUNK = 1024


def sdpa_qchunked(q, k, v, q_pos, k_pos, window, cap,
                  causal: bool = True, chunk: int = QCHUNK):
    """Blockwise attention over query chunks.  q [B,S,H,D] -> [B,S,H,D].
    Peak temp is O(chunk * Sk) instead of O(Sq * Sk)."""
    B, S, H, Dh = q.shape
    chunk = min(chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
        S_p = S + pad
    else:
        S_p = S
    nc = S_p // chunk
    qc = jnp.moveaxis(q.reshape(B, nc, chunk, H, Dh), 1, 0)
    pc = jnp.moveaxis(q_pos.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def one(args):
        qi, pi = args                                     # [B,chunk,H,D],[B,chunk]
        bias = _mask_bias(pi, k_pos, window, causal)      # [B,chunk,Sk]
        return sdpa(qi, k, v, bias, cap)

    out = jax.lax.map(one, (qc, pc))                      # [nc,B,chunk,H,D]
    out = jnp.moveaxis(out, 0, 1).reshape(B, S_p, H, Dh)
    return out[:, :S]


def attend_full(params, cfg: ArchConfig, x, positions, window: Optional[int]):
    """Full-sequence (train / prefill) attention.  Returns (out, (k, v))."""
    a: AttnConfig = cfg.attn
    hd = cfg.hd
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if a.rope:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    S = q.shape[1]
    if S > QCHUNK_THRESHOLD:
        out = sdpa_qchunked(q, k, v, positions, positions, window,
                            a.logit_softcap)
    else:
        bias = _mask_bias(positions, positions, window)   # [B,S,S] or [S,S]
        if bias.ndim == 2:
            bias = bias[None]
        out = sdpa(q, k, v, bias, a.logit_softcap)
    return _merge_heads(out) @ params["wo"], (k, v)


class KVCache(NamedTuple):
    """Fixed-capacity ring buffer per layer stack.

    k, v: [L, B, C, Hkv, Dh] where C = capacity (window or full seq).
    idx:  scalar int32 — number of tokens written so far (global position).
    """
    k: jax.Array
    v: jax.Array
    idx: jax.Array

    @property
    def capacity(self) -> int:
        return self.k.shape[2]


def kv_cache_init(cfg: ArchConfig, n_layers: int, batch: int, capacity: int, dtype):
    shape = (n_layers, batch, capacity, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def attend_decode(params, cfg: ArchConfig, x, layer_k, layer_v, pos,
                  window: Optional[int]):
    """One-token decode against a ring-buffer cache slice.

    x: [B, 1, D]; layer_k/v: [B, C, Hkv, Dh]; pos: scalar int32 (global
    position of the new token).  Returns (out [B,1,D], new_k, new_v).
    """
    a: AttnConfig = cfg.attn
    hd = cfg.hd
    C = layer_k.shape[1]
    q = _split_heads(x @ params["wq"], cfg.n_heads, hd)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if a.rope:
        posb = jnp.full((x.shape[0], 1), pos, jnp.int32)
        q = apply_rope(q, posb, a.rope_theta)
        k = apply_rope(k, posb, a.rope_theta)
    slot = pos % C
    layer_k = layer_k.at[:, slot].set(k[:, 0])
    layer_v = layer_v.at[:, slot].set(v[:, 0])
    # absolute position of every cache slot (ring semantics)
    slots = jnp.arange(C, dtype=jnp.int32)
    # slot s holds global position: largest p <= pos with p % C == s
    k_pos = pos - ((pos - slots) % C)
    valid = k_pos >= 0
    if window is not None:
        valid &= (pos - k_pos) < window
    bias = jnp.where(valid, 0.0, NEG_INF)[None, None, :]     # [1,1,C]
    out = sdpa(q, layer_k, layer_v, bias, a.logit_softcap)
    return _merge_heads(out) @ params["wo"], layer_k, layer_v


def layer_window(cfg: ArchConfig, layer_idx_is_local: bool,
                 long_context: bool) -> Optional[int]:
    """Resolve the effective sliding window for a layer.

    - pattern 'global': no window, unless long_context forces the
      carve-out window (sub-quadratic serving variant, see DESIGN.md).
    - pattern 'local_global': even layers local (cfg.attn.window), odd
      global (windowed only in long_context mode).
    """
    a = cfg.attn
    if a.pattern == "local_global" and layer_idx_is_local:
        return a.window
    if long_context:
        return cfg.long_context_window
    if a.pattern == "local":
        return a.window
    return None
