"""LEAF Shakespeare LSTM (paper Table 12) as a StageModel.

Stage layout mirrors the paper's cut: embeddings + LSTM cells on the
client, projection head on the server (cut = 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module
from repro.models.cnn import StageModel


def _lstm_cell_init(key, d_in: int, d_h: int):
    k1, k2 = jax.random.split(key)
    return {
        "w_x": module.dense_init(k1, d_in, 4 * d_h),
        "w_h": module.dense_init(k2, d_h, 4 * d_h),
        "b": jnp.zeros((4 * d_h,)),
    }


def _lstm_layer(params, x):
    """x [B, S, d_in] -> hidden sequence [B, S, d_h]."""
    B = x.shape[0]
    d_h = params["w_h"].shape[0]

    def step(carry, xt):
        h, c = carry
        z = xt @ params["w_x"] + h @ params["w_h"] + params["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((B, d_h))
    (_, _), hs = jax.lax.scan(step, (h0, h0), jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def shakespeare_lstm(vocab: int = 80, d_embed: int = 8,
                     d_h: int = 256, n_lstm: int = 2) -> StageModel:
    """Stages: [embed, lstm-stack, head].  Cut=2 keeps embed+LSTM on the
    client, the linear head on the server — the paper's Shakespeare cut."""

    def emb_init(k):
        return {"table": module.embed_init(k, vocab, d_embed)}

    def emb(p, ids):
        return jnp.take(p["table"], ids, axis=0)

    def lstm_init(k):
        keys = jax.random.split(k, n_lstm)
        return {"cells": [
            _lstm_cell_init(keys[i], d_embed if i == 0 else d_h, d_h)
            for i in range(n_lstm)]}

    def lstm(p, x):
        for cell in p["cells"]:
            x = _lstm_layer(cell, x)
        return x[:, -1]                     # last hidden state

    def head_init(k):
        return {"w": module.dense_init(k, d_h, vocab)}

    def head(p, x):
        return x @ p["w"]

    return StageModel("shakespeare_lstm",
                      [(emb_init, emb), (lstm_init, lstm), (head_init, head)],
                      vocab)
