"""Encoder-decoder transformer (whisper-base backbone).

The mel-spectrogram + conv feature extractor is a STUB per the
assignment: ``input_specs`` supplies precomputed frame embeddings
[B, T_frames, d].  This module implements the transformer backbone:
bidirectional encoder + causal decoder with cross-attention.

Split-learning mapping (DESIGN.md §5): the encoder is the natural client
part, the decoder the server part — the enc/dec boundary is the cut.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models import module
from repro.models.attention import KVCache, kv_cache_init, sdpa
from repro.models.layers import (embedding, embedding_init, layernorm,
                                 layernorm_init)
from repro.models.module import stacked_init

N_AUDIO_FRAMES = 1500  # whisper: 30s @ 50 fps after conv stub


def _enc_block_init(key, cfg: ArchConfig, dtype):
    ka, kf = jax.random.split(key)
    return {
        "attn": attn_lib.attn_init(ka, cfg, dtype),
        "ffn": ffn_lib.gelu_mlp_init(kf, cfg.d_model, cfg.d_ff, dtype),
        "norm_attn": layernorm_init(cfg.d_model, dtype),
        "norm_ffn": layernorm_init(cfg.d_model, dtype),
    }


def _dec_block_init(key, cfg: ArchConfig, dtype):
    ka, kx, kf = jax.random.split(key, 3)
    return {
        "self_attn": attn_lib.attn_init(ka, cfg, dtype),
        "cross_attn": attn_lib.attn_init(kx, cfg, dtype),
        "ffn": ffn_lib.gelu_mlp_init(kf, cfg.d_model, cfg.d_ff, dtype),
        "norm_self": layernorm_init(cfg.d_model, dtype),
        "norm_cross": layernorm_init(cfg.d_model, dtype),
        "norm_ffn": layernorm_init(cfg.d_model, dtype),
    }


class EncDec:
    @staticmethod
    def init(key, cfg: ArchConfig):
        dtype = cfg.jnp_dtype
        ke, kp, kq, kb, kd = jax.random.split(key, 5)
        return {
            "encoder": {
                "pos": (jax.random.normal(kp, (N_AUDIO_FRAMES, cfg.d_model),
                                          jnp.float32) * 0.01).astype(dtype),
                "blocks": stacked_init(lambda k: _enc_block_init(k, cfg, dtype),
                                       kb, cfg.enc_layers),
                "final_norm": layernorm_init(cfg.d_model, dtype),
            },
            "decoder": {
                "embed": embedding_init(ke, cfg.vocab_padded, cfg.d_model, dtype),
                "pos": (jax.random.normal(kq, (448, cfg.d_model),
                                          jnp.float32) * 0.01).astype(dtype),
                "blocks": stacked_init(lambda k: _dec_block_init(k, cfg, dtype),
                                       kd, cfg.n_layers),
                "final_norm": layernorm_init(cfg.d_model, dtype),
            },
        }

    # ---------------- encoder (client part) ----------------
    @staticmethod
    def encode(enc_params, cfg: ArchConfig, frames):
        """frames [B, T, d] (stub conv output) -> encoder states."""
        B, T, _ = frames.shape
        x = frames + enc_params["pos"][:T][None]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

        def body(xs, bp):
            h = layernorm(bp["norm_attn"], xs, cfg.norm_eps)
            # bidirectional: no causal mask; q-chunked above the threshold
            # (perf iteration: encoder frames are 1500 long)
            hd = cfg.hd
            q = (h @ bp["attn"]["wq"]).reshape(B, T, cfg.n_heads, hd)
            k = (h @ bp["attn"]["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
            v = (h @ bp["attn"]["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
            if T > 512:
                a = attn_lib.sdpa_qchunked(
                    q, k, v, positions, positions, None, None,
                    causal=False, chunk=512)
            else:
                a = sdpa(q, k, v, jnp.zeros((1, T, T), jnp.float32))
            xs = xs + a.reshape(B, T, -1) @ bp["attn"]["wo"]
            h = layernorm(bp["norm_ffn"], xs, cfg.norm_eps)
            return xs + ffn_lib.gelu_mlp(bp["ffn"], h), None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, enc_params["blocks"])
        return layernorm(enc_params["final_norm"], x, cfg.norm_eps)

    # ---------------- decoder (server part) ----------------
    @staticmethod
    def _cross_attend(bp, cfg: ArchConfig, h, enc_out):
        B, S, _ = h.shape
        T = enc_out.shape[1]
        hd = cfg.hd
        q = (h @ bp["wq"]).reshape(B, S, cfg.n_heads, hd)
        k = (enc_out @ bp["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
        v = (enc_out @ bp["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
        bias = jnp.zeros((1, S, T), jnp.float32)
        return sdpa(q, k, v, bias).reshape(B, S, -1) @ bp["wo"]

    @staticmethod
    def decode_train(dec_params, cfg: ArchConfig, tokens, enc_out):
        """Teacher-forced decoder forward.  tokens [B,S] -> logits."""
        B, S = tokens.shape
        x = embedding(dec_params["embed"], tokens)
        x = x + dec_params["pos"][:S][None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(xs, bp):
            h = layernorm(bp["norm_self"], xs, cfg.norm_eps)
            a, _ = attn_lib.attend_full(bp["self_attn"], cfg, h, positions, None)
            xs = xs + a
            h = layernorm(bp["norm_cross"], xs, cfg.norm_eps)
            xs = xs + EncDec._cross_attend(bp["cross_attn"], cfg, h, enc_out)
            h = layernorm(bp["norm_ffn"], xs, cfg.norm_eps)
            return xs + ffn_lib.gelu_mlp(bp["ffn"], h), None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, dec_params["blocks"])
        x = layernorm(dec_params["final_norm"], x, cfg.norm_eps)
        return EncDec._logits(dec_params, cfg, x)

    @staticmethod
    def _logits(dec_params, cfg: ArchConfig, x):
        """Unembed against the PADDED table (vocab shards on the model
        axis); padded columns masked, then sliced off."""
        logits = (x @ dec_params["embed"]["table"].T).astype(jnp.float32)
        return logits[..., :cfg.vocab]

    @staticmethod
    def forward(params, cfg: ArchConfig, frames, tokens):
        enc_out = EncDec.encode(params["encoder"], cfg, frames)
        return EncDec.decode_train(params["decoder"], cfg, tokens, enc_out)

    @staticmethod
    def loss_fn(params, cfg: ArchConfig, frames, tokens, labels):
        logits = EncDec.forward(params, cfg, frames, tokens)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(nll), {}

    # ---------------- serving ----------------
    @staticmethod
    def init_decode_state(params, cfg: ArchConfig, frames, seq_len: int,
                          long_context: bool = False):
        """Encode once; allocate self-attn cache (optionally windowed)."""
        enc_out = EncDec.encode(params["encoder"], cfg, frames)
        cap = seq_len if not long_context else min(seq_len, cfg.long_context_window)
        B = frames.shape[0]
        kv = kv_cache_init(cfg, cfg.n_layers, B, cap, cfg.jnp_dtype)
        return {"enc_out": enc_out, "kv": kv, "pos": jnp.zeros((), jnp.int32)}

    @staticmethod
    def decode_step(params, cfg: ArchConfig, token, state,
                    long_context: bool = False):
        dec = params["decoder"]
        pos = state["pos"]
        kv: KVCache = state["kv"]
        enc_out = state["enc_out"]
        x = embedding(dec["embed"], token)
        x = x + jax.lax.dynamic_index_in_dim(
            dec["pos"], jnp.minimum(pos, dec["pos"].shape[0] - 1),
            keepdims=True)[None]
        window = cfg.long_context_window if long_context else None

        def body(xs, inp):
            bp, lk, lv = inp
            h = layernorm(bp["norm_self"], xs, cfg.norm_eps)
            a, nk, nv = attn_lib.attend_decode(bp["self_attn"], cfg, h,
                                               lk, lv, pos, window)
            xs = xs + a
            h = layernorm(bp["norm_cross"], xs, cfg.norm_eps)
            xs = xs + EncDec._cross_attend(bp["cross_attn"], cfg, h, enc_out)
            h = layernorm(bp["norm_ffn"], xs, cfg.norm_eps)
            return xs + ffn_lib.gelu_mlp(bp["ffn"], h), (nk, nv)

        xs, (nk, nv) = jax.lax.scan(body, x, (dec["blocks"], kv.k, kv.v))
        x = layernorm(dec["final_norm"], xs, cfg.norm_eps)
        logits = EncDec._logits(dec, cfg, x)
        state = dict(state, kv=KVCache(nk, nv, kv.idx + 1), pos=pos + 1)
        return logits, state
