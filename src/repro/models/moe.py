"""Mixture-of-Experts layer with sort-based (gather/scatter) dispatch.

Design notes (TPU adaptation, see DESIGN.md §4/§5):

* Dispatch is *sort-based*, not one-hot-einsum based: assignments are
  sorted by expert id, ranked within expert, and gathered into a
  capacity-bounded ``[E, C, d]`` buffer.  This keeps HLO FLOPs equal to
  the *active* expert FLOPs (×capacity slack) instead of the ×(E/k)
  inflation of dense-compute MoE — which matters because the roofline
  compute term is derived from HLO FLOPs.
* Expert parallelism is expressed with sharding constraints only; GSPMD
  inserts the all-to-alls.  ``shard_mode='expert'`` shards the expert dim
  over the ``model`` axis (64-expert archs); ``shard_mode='ffn'`` shards
  the per-expert hidden dim instead (grok-1: 8 experts on a 16-way axis).
* Tokens that overflow expert capacity are dropped (standard GShard
  semantics); the router's combine weight renormalizes the survivors.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import module

try:  # sharding constraint is a no-op outside a mesh context
    from jax.sharding import PartitionSpec as P
    from jax.lax import with_sharding_constraint as _wsc
except Exception:  # pragma: no cover
    P = None
    _wsc = None


def _constrain(x, spec):
    if _wsc is None or spec is None:
        return x
    try:
        return _wsc(x, P(*spec))
    except Exception:
        return x


def moe_init(key, d: int, mcfg: MoEConfig, dtype):
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, f = mcfg.n_experts, mcfg.d_ff_expert
    sub = lambda k, din, dout: module.stacked_init(
        lambda kk: module.dense_init(kk, din, dout, dtype), k, E)
    return {
        "router": module.dense_init(kr, d, E, jnp.float32, scale=0.02),
        "w_gate": sub(kg, d, f),
        "w_up": sub(ku, d, f),
        "w_down": sub(kd, f, d),
    }


def router_probs(params, x2d):
    """x2d [T, d] -> (probs [T, E] fp32, logits fp32)."""
    logits = x2d.astype(jnp.float32) @ params["router"]
    return jax.nn.softmax(logits, axis=-1), logits


def _dispatch_group(params, mcfg: MoEConfig, x2):
    """Sort-based dispatch+combine for ONE token group.  x2 [S, d]."""
    S, d = x2.shape
    E, k = mcfg.n_experts, mcfg.top_k
    C = max(1, int(S * k / E * mcfg.capacity_factor))

    probs, logits = router_probs(params, x2)                     # [S,E] fp32
    top_p, top_e = jax.lax.top_k(probs, k)                       # [S,k]
    top_p = top_p / jnp.clip(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # ---- flatten assignments and sort by expert ----
    flat_e = top_e.reshape(-1)                                   # [S*k]
    flat_t = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)       # [S*k]
    flat_w = top_p.reshape(-1)                                   # [S*k]
    order = jnp.argsort(flat_e)                                  # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert segment
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))  # [E]
    rank = jnp.arange(S * k, dtype=jnp.int32) - starts[se]
    keep = rank < C
    slot = se * C + jnp.minimum(rank, C - 1)                      # [S*k]

    # ---- gather tokens into the expert buffer [E*C, d] ----
    buf = jnp.zeros((E * C, d), x2.dtype)
    rows = x2[st] * keep[:, None].astype(x2.dtype)
    buf = buf.at[slot].add(rows, mode="drop")
    buf = buf.reshape(E, C, d)

    # ---- per-expert SwiGLU: batched matmuls [E,C,d]x[E,d,f] ----
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    yb = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"])
    yb = yb.reshape(E * C, d)

    # ---- scatter-combine back to tokens ----
    contrib = yb[slot] * (sw * keep.astype(jnp.float32)).astype(x2.dtype)[:, None]
    y = jnp.zeros((S, d), x2.dtype).at[st].add(contrib)

    # ---- router losses (per group; averaged by the caller) ----
    me = jnp.mean(probs, axis=0)                                  # [E]
    one_hot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)         # [S,k,E]
    ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0) / k
    aux = E * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return y, aux, z, ce


def moe_apply(params, mcfg: MoEConfig, x, *, expert_spec: Optional[tuple] = None):
    """Apply the MoE block.  x: [..., d] -> (y, metrics).

    Tokens are dispatched in GROUPS of ``mcfg.group_size`` (GShard-style
    per-group capacity, §Perf iteration 7): a single global sort/scatter
    has no shardable dim for GSPMD and replicated 100+ GiB dispatch
    buffers per device; the vmapped group dim shards over 'data' and
    bounds the per-group buffer to [E, S·k/E·cf, d].

    metrics = {'aux_loss', 'z_loss', 'load'}; add
    ``mcfg.aux_weight*aux_loss + mcfg.router_z_weight*z_loss`` to the
    task loss at the call site.
    """
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    T = x2.shape[0]
    gs = min(mcfg.group_size, T)
    n_pad = (-T) % gs
    if n_pad:
        x2 = jnp.pad(x2, ((0, n_pad), (0, 0)))
    G = x2.shape[0] // gs
    xg = x2.reshape(G, gs, d)

    # NOTE (§Perf iteration 7b, refuted/blocked): the sort/scatter inside
    # the dispatch defeats GSPMD's sharding propagation, so the group dim
    # of the expert hiddens replicates on MoE archs.  A partial-manual
    # shard_map over 'data' fixes the isolated case but aborts natively
    # when composed with the CycleSL cohort vmap + remat in this jax
    # build; the grouped vmap below is the safe point in that trade-off
    # (it already bounds the dispatch *buffers* per group).
    xg = _constrain(xg, ("data", None, None))
    yg, aux, z, ce = jax.vmap(lambda g: _dispatch_group(params, mcfg, g))(xg)
    yg = _constrain(yg, ("data", None, None))
    y = yg.reshape(G * gs, d)[:T]

    metrics = {"aux_loss": jnp.mean(aux), "z_loss": jnp.mean(z),
               "load": jnp.mean(ce, axis=0)}
    return y.reshape(orig_shape), metrics


def expert_partition_spec(mcfg: MoEConfig):
    """Sharding of the [E, C, d] dispatch buffer (see module docstring)."""
    if mcfg.shard_mode == "expert":
        return ("model", None, None)
    return (None, None, "model")  # 'ffn': shard d of the buffer? keep replicated
