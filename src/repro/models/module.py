"""Tiny functional-module helpers (no flax): initializers + RNG plumbing."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def rng_seq(key, n: int):
    """Split a key into n keys (deterministic fan-out)."""
    return list(jax.random.split(key, n))


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    """Truncated-normal fan-in init (LeCun-style) used for all projections."""
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32)
    return (w * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, d), jnp.float32)
    return (w * 0.02).astype(dtype)


def stacked_init(init_fn, key, n: int):
    """vmap an init over a leading layer dim -> stacked params for lax.scan."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def zeros(shape: Sequence[int], dtype=jnp.float32):
    return jnp.zeros(tuple(shape), dtype)


def ones(shape: Sequence[int], dtype=jnp.float32):
    return jnp.ones(tuple(shape), dtype)
