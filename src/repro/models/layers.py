"""Primitive layers: norms, linear, embedding, rotary tables."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module


# ---------------------------------------------------------------- norms
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * params["scale"].astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------- linear
def linear_init(key, d_in: int, d_out: int, dtype=jnp.float32, bias: bool = False):
    p = {"w": module.dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ------------------------------------------------------------ embedding
def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": module.embed_init(key, vocab, d, dtype)}


def embedding(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x):
    """Tied unembedding: x @ table.T."""
    return x @ params["table"].T


# --------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [Dh/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]                          # [..., S, 1, Dh/2]
    cos = cos[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: float | None):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
