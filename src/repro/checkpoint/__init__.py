from repro.checkpoint.io import (save_checkpoint, load_checkpoint,
                                 load_metadata, latest_step,
                                 checkpoint_valid, valid_steps)
