"""Pytree checkpointing: flat-path .npz payload + JSON manifest.

Layout:  <dir>/step_<n>/arrays.npz  +  <dir>/step_<n>/manifest.json
The manifest stores the flattened key paths and scalar metadata, so a
checkpoint round-trips to an *identical* tree structure (dict/list/
NamedTuple nesting is re-assembled from the paths of a template tree,
or from plain nested dicts when no template is given).
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np

from repro.utils.tree import path_str

_STEP_RE = re.compile(r"step_(\d+)$")


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {path_str(kp): np.asarray(v) for kp, v in flat}, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, metadata: dict | None = None,
                    keep: int = 3) -> str:
    out = os.path.join(ckpt_dir, f"step_{step}")
    tmp = out + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "paths": sorted(flat),
                   "metadata": metadata or {}}, f, indent=2)
    if os.path.exists(out):
        shutil.rmtree(out)
    os.rename(tmp, out)
    _gc(ckpt_dir, keep)
    return out


def load_checkpoint(ckpt_dir: str, template, step: int | None = None):
    """Restore into the structure of ``template`` (arbitrary pytree)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, tmpl_leaf in flat:
        key = path_str(kp)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        leaves.append(jax.numpy.asarray(arr, dtype=tmpl_leaf.dtype)
                      if hasattr(tmpl_leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := _STEP_RE.search(d))]
    return max(steps) if steps else None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted([int(m.group(1)) for d in os.listdir(ckpt_dir)
                    if (m := _STEP_RE.search(d))])
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
