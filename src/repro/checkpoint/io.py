"""Pytree checkpointing: flat-path .npz payload + JSON manifest.

Layout:  <dir>/step_<n>/arrays.npz  +  <dir>/step_<n>/manifest.json
The manifest stores the flattened key paths and scalar metadata, so a
checkpoint round-trips to an *identical* tree structure (dict/list/
NamedTuple nesting is re-assembled from the paths of a template tree,
or from plain nested dicts when no template is given).

Crash safety contract (format 2):

* Writes are atomic: the payload + manifest land in a hidden temp dir
  (fsync'd file-by-file, then the directory), which is renamed into
  place in one step.  A SIGKILL at any instant leaves either the old
  step set or the new one — never a half-written ``step_<n>``.
* The manifest carries a CRC-32 of ``arrays.npz``, so a torn payload
  (truncated file, bit rot) is detectable without parsing it.
* Readers are fallback-tolerant: :func:`latest_step` and
  :func:`load_checkpoint` skip unreadable or checksum-failing step dirs
  with a warning and fall back to the newest VALID step.
* :func:`_gc` never deletes the newest valid step, whatever ``keep``
  says — a run can always resume from something.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import warnings
import zlib

import jax
import numpy as np

from repro.utils.tree import path_str

# anchored full-name match: in-progress temp dirs never parse as steps
_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_PREFIX = ".tmp-"
CHECKPOINT_FORMAT = 2


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {path_str(kp): np.asarray(v) for kp, v in flat}, treedef


def _crc32(path: str, chunk: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while block := f.read(chunk):
            crc = zlib.crc32(block, crc)
    return crc & 0xFFFFFFFF


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(ckpt_dir: str, step: int, tree, metadata: dict | None = None,
                    keep: int = 3) -> str:
    out = os.path.join(ckpt_dir, f"step_{step}")
    tmp = os.path.join(ckpt_dir, f"{_TMP_PREFIX}step_{step}-{os.getpid()}")
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = os.path.join(tmp, "arrays.npz")
    with open(arrays, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    manifest = {"format": CHECKPOINT_FORMAT, "step": step,
                "paths": sorted(flat),
                "checksum": {"arrays.npz": _crc32(arrays)},
                "metadata": metadata or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(tmp)
    if os.path.exists(out):
        shutil.rmtree(out)
    os.rename(tmp, out)
    _fsync_path(ckpt_dir)
    _gc(ckpt_dir, keep)
    return out


def checkpoint_valid(path: str) -> bool:
    """Whether ``path`` (a ``step_<n>`` dir) holds a loadable checkpoint.

    Format-2 dirs verify the manifest's CRC-32 against the payload
    bytes; legacy (pre-checksum) dirs fall back to parsing the payload
    with ``np.load``.  Any IO/parse failure means invalid — callers skip
    and fall back, they never raise here.
    """
    arrays = os.path.join(path, "arrays.npz")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        expect = manifest.get("checksum", {}).get("arrays.npz")
        if expect is not None:
            return _crc32(arrays) == int(expect)
        with np.load(arrays) as data:          # legacy: no checksum
            missing = set(manifest.get("paths", [])) - set(data.files)
        return not missing
    except Exception:
        return False


def _all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                  if (m := _STEP_RE.fullmatch(d)))


def valid_steps(ckpt_dir: str, warn: bool = True) -> list[int]:
    """Ascending step numbers whose dirs pass :func:`checkpoint_valid`;
    invalid dirs are reported once via ``warnings.warn``."""
    good = []
    for s in _all_steps(ckpt_dir):
        path = os.path.join(ckpt_dir, f"step_{s}")
        if checkpoint_valid(path):
            good.append(s)
        elif warn:
            warnings.warn(f"skipping corrupt/partial checkpoint {path}",
                          RuntimeWarning, stacklevel=2)
    return good


def latest_step(ckpt_dir: str) -> int | None:
    steps = valid_steps(ckpt_dir)
    return steps[-1] if steps else None


def _load_step(ckpt_dir: str, template, step: int):
    path = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for kp, tmpl_leaf in flat:
            key = path_str(kp)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            leaves.append(jax.numpy.asarray(arr, dtype=tmpl_leaf.dtype)
                          if hasattr(tmpl_leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def load_checkpoint(ckpt_dir: str, template, step: int | None = None):
    """Restore into the structure of ``template`` (arbitrary pytree).

    With ``step=None``, walks valid steps newest-first and returns the
    first that actually loads, warning past any that fail mid-read (the
    checksum pass and the load race against nothing — a dir can still
    vanish under gc from a concurrent writer).  An explicit ``step``
    loads exactly that step or raises.
    """
    if step is not None:
        return _load_step(ckpt_dir, template, step)
    failures = []
    for s in reversed(valid_steps(ckpt_dir)):
        try:
            return _load_step(ckpt_dir, template, s)
        except Exception as e:  # pragma: no cover - vanishing-dir race
            failures.append(f"step_{s}: {e}")
            warnings.warn(f"failed to load checkpoint step_{s} ({e}); "
                          "falling back", RuntimeWarning, stacklevel=2)
    detail = f" (tried: {failures})" if failures else ""
    raise FileNotFoundError(f"no loadable checkpoints under {ckpt_dir}"
                            f"{detail}")


def load_metadata(ckpt_dir: str, step: int) -> dict:
    """The manifest's ``metadata`` dict for one step (``{}`` when the
    manifest predates metadata or carries none).  Cheap — reads only the
    JSON manifest, never the array payload."""
    path = os.path.join(ckpt_dir, f"step_{step}", "manifest.json")
    with open(path) as f:
        return json.load(f).get("metadata") or {}


def _gc(ckpt_dir: str, keep: int):
    """Prune old steps and stale temp dirs.

    Only VALID steps count toward ``keep``, and the newest valid step is
    never deleted — even with ``keep=0`` a crash-interrupted run keeps a
    resume point.  Invalid (corrupt) step dirs older than the newest
    valid one are reclaimed.
    """
    good = valid_steps(ckpt_dir, warn=False)
    protect = set(good if keep <= 0 else good[-max(keep, 1):])
    newest_valid = good[-1] if good else None
    for s in _all_steps(ckpt_dir):
        if s in protect:
            continue
        if s not in good and (newest_valid is None or s > newest_valid):
            continue  # corrupt-but-newer: leave for post-mortem
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)
    for d in os.listdir(ckpt_dir):
        if d.startswith(_TMP_PREFIX) \
                and not d.endswith(f"-{os.getpid()}"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
