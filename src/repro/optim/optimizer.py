"""Minimal pure-function optimizers over pytrees (no optax).

An ``Optimizer`` is an (init, update) pair:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

The paper uses Adam everywhere ("less sensitive to learning rate", §4.1)
with *independent* optimizers per entity: each client owns an Adam state
for its θ_C, the server owns one for θ_S — that independence is load-
bearing for CycleSL's "standalone higher-level task" framing, so the
optimizer state is explicitly part of each entity's state in repro.core.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]   # (grads, state, params, step)
    # optional fused step: (grads, state, params, step) -> (params', state').
    # When set, repro.core.protocol.entity_step uses it instead of
    # update + apply_updates — one kernel pass over each leaf instead of
    # a chain of unfused elementwise tree-maps (the Pallas fused-Adam
    # path).  Must be numerically equivalent to the update path.
    apply: Optional[Callable[..., tuple[Any, Any]]] = None


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def sgd(lr: float | Callable[[Any], Any], momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else (lambda step: lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params=None, step=0):
        lr_t = sched(step)
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads), state
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        return jax.tree.map(lambda m: -lr_t * m, new_m), new_m

    return Optimizer(init, update)


def adam(lr: float | Callable[[Any], Any], b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         fused: Optional[bool] = None) -> Optimizer:
    """Adam with an optional fused (Pallas) step.

    ``fused=None`` auto-selects: the fused kernel runs compiled on TPU
    and is skipped elsewhere (the interpreter would be slower than the
    jnp tree-map path).  ``fused=True`` forces it — off-TPU that means
    Pallas interpret mode, which is what the equivalence tests exercise.
    Fusion requires a constant ``lr`` (the kernel specializes on it);
    schedules fall back to the jnp path.
    """
    sched = lr if callable(lr) else (lambda step: lr)
    if fused is None:
        fused = (not callable(lr)) and jax.default_backend() == "tpu"
    if fused and callable(lr):
        raise ValueError("fused adam requires a constant lr "
                         "(the kernel specializes on it); pass fused=False "
                         "for schedules")

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params=None, step=0):
        t = jnp.asarray(step, jnp.float32) + 1.0
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], gf)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], gf)
        mh = jax.tree.map(lambda x: x / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda x: x / (1 - b2 ** t), v)
        lr_t = sched(step)
        upd = jax.tree.map(
            lambda mm, vv: -lr_t * mm / (jnp.sqrt(vv) + eps), mh, vh)
        if weight_decay and params is not None:
            upd = jax.tree.map(
                lambda u, p: u - lr_t * weight_decay * p.astype(jnp.float32),
                upd, params)
        return upd, {"m": m, "v": v}

    def apply(grads, state, params, step):
        # leaf-wise fused update: each (p, g, m, v) streams through VMEM
        # exactly once per step instead of once per tree-map above
        from repro.kernels import ops
        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_m = treedef.flatten_up_to(state["m"])
        leaves_v = treedef.flatten_up_to(state["v"])
        outs = [ops.fused_adam(p, g, m, v, step, lr=lr, b1=b1, b2=b2,
                               eps=eps, weight_decay=weight_decay)
                for p, g, m, v in zip(leaves_p, leaves_g, leaves_m, leaves_v)]
        return (treedef.unflatten([o[0] for o in outs]),
                {"m": treedef.unflatten([o[1] for o in outs]),
                 "v": treedef.unflatten([o[2] for o in outs])})

    return Optimizer(init, update, apply if fused else None)


def clip_by_global_norm(grads, max_norm: float):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    gn = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn
