from repro.optim.optimizer import Optimizer, adam, sgd, clip_by_global_norm
from repro.optim import schedule
