"""Learning-rate schedules as step -> lr functions."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    if warmup < 0:
        raise ValueError(f"cosine schedule: warmup={warmup} must be >= 0")
    if total <= warmup:
        # max(1, total - warmup) would silently collapse the decay
        # window to a single step (lr cliffs from lr to final_frac*lr
        # between steps `warmup` and `warmup+1`) — reject upfront
        raise ValueError(f"cosine schedule: total={total} must exceed "
                         f"warmup={warmup} (no decay window otherwise)")

    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(1.0, s / jnp.maximum(1, warmup))
        prog = jnp.clip((s - warmup) / (total - warmup), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, lr * cos)
    return f


def exponential_decay(lr: float, decay: float, every: int):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        return lr * decay ** (s / every)
    return f
