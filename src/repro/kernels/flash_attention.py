"""Blocked online-softmax (flash) attention — Pallas TPU kernel.

TPU adaptation notes (DESIGN.md §4): tiles are MXU-aligned (q-block ×
kv-block of 128×128 by default, head_dim padded to a lane multiple);
the kv loop is the innermost *grid* dimension so the (acc, m, l)
scratch carries across kv blocks in VMEM — the standard TPU flash
pattern (no warp-level shuffles; the online-softmax state lives in
VMEM scratch instead).

Supports GQA (kv-head picked by index_map, no materialized repeat),
causal masking, sliding windows, and gemma2 logit softcap.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 block_q: int, block_k: int, seq_k: int, causal: bool,
                 window: Optional[int], softcap: Optional[float],
                 scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # [Bq, D]
    k = k_ref[0, 0].astype(jnp.float32)                    # [Bk, D]
    v = v_ref[0, 0].astype(jnp.float32)                    # [Bk, D]
    s = q @ k.T                                            # [Bq, Bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    valid = kpos < seq_k
    if causal:
        valid &= qpos >= kpos
    if window is not None:
        valid &= (qpos - kpos) < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                    # [Bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = corr * acc_ref[...] + p @ v
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q [B,Sq,H,D]; k/v [B,Sk,Hkv,D] -> [B,Sq,H,D].

    GQA: q-head h reads kv-head h // (H//Hkv) via the kv BlockSpec
    index_map — the kv tensor is never repeated in HBM.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    # layout: [B, H, S, D] blocks
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_k=Sk,
        causal=causal, window=window, softcap=softcap,
        scale=1.0 / (D ** 0.5))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pl_scratch((block_q, D)),
            pl_scratch((block_q, 1)),
            pl_scratch((block_q, 1)),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)


def pl_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
