"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation (DESIGN.md §4): the SSD dual form makes the intra-chunk
work a pair of MXU matmuls ([Q,N]@[N,Q] and [Q,Q]@[Q,P]), and the
inter-chunk recurrence is carried in a VMEM scratch state [N,P] across
the innermost grid dimension (chunks execute in order on TPU) — the
CUDA-style parallel prefix over SMs is replaced by the sequential-grid
+ resident-scratch idiom, which is the natural systolic mapping.

Layout: per (batch, head): x [L,P], dt [L,1], B/C [L,N] (per-head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[0, 0]                                        # scalar
    x = x_ref[0, 0].astype(jnp.float32)                    # [Q,P]
    dt = dt_ref[0, 0].astype(jnp.float32)                  # [Q,1]
    bm = b_ref[0, 0].astype(jnp.float32)                   # [Q,N]
    cm = c_ref[0, 0].astype(jnp.float32)                   # [Q,N]

    dA = dt * A                                            # [Q,1], <= 0
    cum = jnp.cumsum(dA, axis=0)                           # [Q,1]

    # ---- intra-chunk dual form ----
    # (double-where as in models/mamba2.py: masked diffs are positive and
    # would overflow exp / poison gradients)
    diff = cum - cum.T                                     # [Q,Q] cum_i - cum_j
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    w = (cm @ bm.T) * lmat * dt.T                          # [Q,Q]
    y = w @ x                                              # [Q,P]

    # ---- inter-chunk contribution from the carried state ----
    y += (cm * jnp.exp(cum)) @ h_ref[...]                  # [Q,N]@[N,P]

    # ---- state update ----
    last = cum[chunk - 1:chunk]                            # [1,1]
    seg = jnp.exp(last - cum)                              # decay to chunk end
    h_ref[...] = (jnp.exp(last) * h_ref[...]
                  + (bm * (dt * seg)).T @ x)               # [N,Q]@[Q,P]

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = True):
    """x [B,L,H,P]; dt [B,L,H]; A [H]; Bm/Cm [B,L,H,N] (per-head).

    Returns y [B,L,H,P].  L must be a multiple of ``chunk``.
    """
    Bsz, L, H, Pd = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0, f"L={L} % chunk={chunk}"
    nc = L // chunk

    # [B,H,L,*] layouts
    xt = jnp.swapaxes(x, 1, 2)
    dtt = jnp.swapaxes(dt, 1, 2)[..., None]
    bt = jnp.swapaxes(Bm, 1, 2)
    ct = jnp.swapaxes(Cm, 1, 2)
    a2 = jnp.broadcast_to(A[None, :], (Bsz, H)).astype(jnp.float32)

    grid = (Bsz, H, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
            pl.BlockSpec((1, 1, chunk, Pd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, Pd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, L, Pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, Pd), jnp.float32)],
        interpret=interpret,
    )(a2, xt, dtt, bt, ct)
    return jnp.swapaxes(out, 1, 2)
