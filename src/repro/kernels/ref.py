"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None):
    """q [B,Sq,H,D], k/v [B,Sk,Hkv,D] -> [B,Sq,H,D] (fp32 math)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qf = q.astype(jnp.float32) / jnp.sqrt(D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, Sq, Hkv, g, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    delta = qpos - kpos
    valid = jnp.ones_like(delta, bool)
    if causal:
        valid &= delta >= 0
    if window is not None:
        valid &= delta < window
    logits = jnp.where(valid[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, vf)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Sequential (unchunked) SSD reference.

    x [B,L,H,P]; dt [B,L,H]; A [H]; Bm/Cm [B,L,H,N] (already per-head).
    Returns (y [B,L,H,P], h_final [B,H,N,P])."""
    Bsz, L, H, Pd = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp       # [B,H,P],[B,H],[B,H,N],[B,H,N]
        dA = jnp.exp(dtt * A)       # [B,H]
        h = (dA[..., None, None] * h
             + jnp.einsum("bh,bhn,bhp->bhnp", dtt, bt, xt))
        y = jnp.einsum("bhn,bhnp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, N, Pd), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def topk_gating_ref(logits, k: int):
    """softmax -> top-k -> renormalized weights.

    logits [T,E] -> (weights [T,k] fp32, ids [T,k] int32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, k)
    w = w / jnp.clip(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, ids.astype(jnp.int32)


def feature_resample_ref(src, idx):
    """Row gather: out[i] = src[idx[i]].  src [T,D], idx [M] -> [M,D]."""
    return jnp.take(src, idx, axis=0)


def gather_loss_microbatch_ref(src, labels, idx, w, b=None):
    """Fused gather + linear-head cross-entropy oracle (fp32 math).

    ``out[i] = xent(src[idx[i]] @ w (+ b), labels[idx[i]])`` — src
    [T, D], labels [T] int, idx [M], w [D, K], b [K] or None.
    Returns the per-row losses [M] float32; their mean equals
    ``split.xent_loss`` of the unfused gather-then-head path.
    """
    f = jnp.take(src, idx, axis=0).astype(jnp.float32)
    logits = f @ w.astype(jnp.float32)
    if b is not None:
        logits = logits + b.astype(jnp.float32)
    ll = jax.nn.log_softmax(logits, axis=-1)
    y = jnp.take(labels, idx, axis=0)
    return -jnp.take_along_axis(ll, y[:, None].astype(jnp.int32),
                                axis=1)[:, 0]


def fused_adam_ref(p, g, m, v, step, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                   weight_decay=0.0):
    """Reference Adam step (matches repro.optim.adam semantics)."""
    t = jnp.asarray(step, jnp.float32) + 1.0
    gf = g.astype(jnp.float32)
    m2 = b1 * m + (1 - b1) * gf
    v2 = b2 * v + (1 - b2) * gf * gf
    mh = m2 / (1 - b1 ** t)
    vh = v2 / (1 - b2 ** t)
    upd = -lr * mh / (jnp.sqrt(vh) + eps)
    if weight_decay:
        upd = upd - lr * weight_decay * p.astype(jnp.float32)
    return (p.astype(jnp.float32) + upd).astype(p.dtype), m2, v2
