"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels are TPU-targeted and validated against ``ref.py`` in interpret
mode, per the repo's hardware-adaptation contract).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import feature_resample as _fr
from repro.kernels import ssd_scan as _ssd
from repro.kernels import topk_gating as _tk


def default_interpret() -> bool:
    """One backend gate for every kernel: compiled on TPU, Pallas
    interpreter everywhere else (the kernels are TPU-targeted and the
    interpreter is the validated CPU fallback)."""
    return jax.default_backend() != "tpu"


_default_interpret = default_interpret            # backwards-compat alias


@partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k,
                               interpret=default_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128):
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                         interpret=default_interpret())


@partial(jax.jit, static_argnames=("k", "block_t"))
def topk_gating(logits, k: int, *, block_t: int = 1024):
    return _tk.topk_gating(logits, k, block_t=block_t,
                           interpret=default_interpret())


@jax.jit
def feature_resample(src, idx):
    return _fr.feature_resample(src, idx, interpret=default_interpret())


def resample_rows(src, idx):
    """Row gather ``out[i] = src[idx[i]]`` for ANY trailing shape via the
    ``feature_resample`` scalar-prefetch kernel (rows flattened to 2-D
    and restored).  This is the entry point ``FeatureStore``'s resample
    gather dispatches to on TPU (backend-gated like ``fused_adam``); it
    deliberately stays un-jitted so it inlines into the caller's trace
    and composes with GSPMD sharding of the pooled array."""
    flat = src.reshape((src.shape[0], -1))
    out = _fr.feature_resample(flat, idx, interpret=default_interpret())
    return out.reshape((idx.shape[0],) + src.shape[1:])


def gather_loss_microbatch(src, labels, idx, w, b=None):
    """Fused resample-gather + linear-head cross-entropy per-row losses
    via the ``gather_loss`` scalar-prefetch kernel (rows flattened to
    2-D like ``resample_rows``).  src [T, ...], labels [T] int, idx [M],
    w [prod(...), K] -> [M] float32.  Un-jitted for the same reason as
    ``resample_rows``: it inlines into the server inner loop's trace."""
    from repro.kernels import gather_loss as _gl
    flat = src.reshape((src.shape[0], -1))
    return _gl.gather_loss_microbatch(flat, labels, idx, w, b,
                                      interpret=default_interpret())


@jax.custom_vjp
def fused_gather_loss_mean(src, labels, idx, w):
    """Mean fused gather+loss over one microbatch, differentiable in the
    head weights ``w`` ONLY (the pooled features are stop_gradient'd by
    construction — paper Eq. 3 treats D_S^f as data).

    Forward streams the pool through the Pallas kernel (the gathered
    batch never materializes); backward is the analytic linear-head
    cross-entropy VJP — ``dw = fᵀ (softmax(logits) − onehot(y)) / M`` —
    recomputed in jnp (the re-gather is one [M, D] read, and M << T).
    """
    return jnp.mean(gather_loss_microbatch(src, labels, idx, w))


def _fglm_fwd(src, labels, idx, w):
    return fused_gather_loss_mean(src, labels, idx, w), (src, labels, idx, w)


def _fglm_bwd(res, g):
    import numpy as np
    src, labels, idx, w = res
    f = jnp.take(src.reshape((src.shape[0], -1)), idx,
                 axis=0).astype(jnp.float32)
    logits = f @ w.astype(jnp.float32)
    y = jnp.take(labels, idx, axis=0)
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, w.shape[1], dtype=jnp.float32)
    dlogits = (p - onehot) * (g / idx.shape[0])
    dw = (f.T @ dlogits).astype(w.dtype)
    zero = lambda x: (np.zeros(x.shape, jax.dtypes.float0)
                      if jnp.issubdtype(x.dtype, jnp.integer)
                      else jnp.zeros_like(x))
    return zero(src), zero(labels), zero(idx), dw


fused_gather_loss_mean.defvjp(_fglm_fwd, _fglm_bwd)


@partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps", "weight_decay"))
def fused_adam(p, g, m, v, step, *, lr: float, b1: float = 0.9,
               b2: float = 0.999, eps: float = 1e-8,
               weight_decay: float = 0.0):
    from repro.kernels import fused_adam as _fa2
    return _fa2.fused_adam(p, g, m, v, step, lr=lr, b1=b1, b2=b2, eps=eps,
                           weight_decay=weight_decay,
                           interpret=default_interpret())
