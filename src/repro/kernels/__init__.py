"""Pallas TPU kernels for the framework's compute hot-spots.

Layout per the repo convention:
  <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     — jit'd public wrappers (interpret=True on CPU)
  ref.py     — pure-jnp oracles the tests assert against

Kernels:
  flash_attention  — blocked online-softmax attention (GQA/causal/window/softcap)
  ssd_scan         — Mamba-2 SSD chunked scan with cross-chunk carry
  topk_gating      — MoE router: softmax + iterative top-k + renorm
  feature_resample — CycleSL resampling gather (scalar-prefetch row gather)
  fused_adam       — one-pass fused Adam update (memory-bound optimizer step)
"""
