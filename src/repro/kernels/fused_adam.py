"""Fused Adam update — Pallas TPU kernel.

The optimizer update is memory-bound: a naive XLA lowering streams
param/grad/m/v through HBM several times across unfused elementwise
ops.  This kernel fuses the whole update (moment updates, bias
correction, parameter step) into one VMEM pass per tile: each operand
is read once and written once — the HBM-optimal schedule.

Operates on flat fp32 views; ``ops.fused_adam_update`` applies it
leaf-wise over a pytree.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, t_ref,
                 p_out, m_out, v_out, *,
                 lr: float, b1: float, b2: float, eps: float,
                 weight_decay: float):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    t = t_ref[0].astype(jnp.float32) + 1.0

    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mh = m / (1.0 - b1 ** t)
    vh = v / (1.0 - b2 ** t)
    upd = -lr * mh / (jnp.sqrt(vh) + eps)
    if weight_decay:
        upd = upd - lr * weight_decay * p
    p_out[...] = (p + upd).astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


def fused_adam(p, g, m, v, step, *, lr: float, b1: float = 0.9,
               b2: float = 0.999, eps: float = 1e-8,
               weight_decay: float = 0.0, block: int = 65536,
               interpret: Optional[bool] = None):
    """One Adam step on flat arrays.  p/g any float dtype, m/v fp32,
    step scalar int32.  Returns (p', m', v').

    ``interpret=None`` selects the mode from the backend (compiled on
    TPU, Pallas interpreter elsewhere) — the same gate
    ``repro.kernels.ops.default_interpret`` applies to every kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = p.size
    p1, g1 = p.reshape(-1), g.reshape(-1)
    m1, v1 = m.reshape(-1), v.reshape(-1)
    block = min(block, n)
    pad = (-n) % block
    if pad:
        p1 = jnp.pad(p1, (0, pad))
        g1 = jnp.pad(g1, (0, pad))
        m1 = jnp.pad(m1, (0, pad))
        v1 = jnp.pad(v1, (0, pad))
    grid = (p1.size // block,)
    t_arr = jnp.full((1,), step, jnp.int32)
    kernel = functools.partial(_adam_kernel, lr=lr, b1=b1, b2=b2, eps=eps,
                               weight_decay=weight_decay)
    p2, m2, v2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p1.shape, p.dtype),
            jax.ShapeDtypeStruct(m1.shape, jnp.float32),
            jax.ShapeDtypeStruct(v1.shape, jnp.float32),
        ],
        interpret=interpret,
    )(p1, g1, m1, v1, t_arr)
    return (p2[:n].reshape(p.shape), m2[:n].reshape(m.shape),
            v2[:n].reshape(v.shape))
