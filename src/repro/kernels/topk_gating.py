"""MoE router top-k gating — Pallas TPU kernel.

Fuses softmax + iterative top-k (k rounds of argmax-and-mask, no sort)
+ renormalization over a token block held in VMEM.  The iterative
top-k is the TPU-idiomatic replacement for CUDA warp-shuffle tournament
reductions: E (the expert dim) lives in lanes, so the per-round max is
one lane reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _gating_kernel(logits_ref, w_ref, id_ref, *, k: int, n_experts: int):
    logits = logits_ref[...].astype(jnp.float32)           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    masked = probs
    ws, ids = [], []
    for _ in range(k):
        top = jnp.max(masked, axis=-1, keepdims=True)       # [T,1]
        eidx = jnp.argmax(masked, axis=-1)                  # [T]
        ws.append(top[:, 0])
        ids.append(eidx)
        onehot = jax.nn.one_hot(eidx, n_experts, dtype=jnp.float32)
        masked = jnp.where(onehot > 0, NEG_INF, masked)
    w = jnp.stack(ws, axis=-1)                              # [T,k]
    w = w / jnp.clip(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    w_ref[...] = w
    id_ref[...] = jnp.stack(ids, axis=-1).astype(jnp.int32)


def topk_gating(logits, k: int, *, block_t: int = 1024, interpret: bool = True):
    """logits [T,E] -> (weights [T,k] fp32 renormalized, ids [T,k] int32)."""
    T, E = logits.shape
    block_t = min(block_t, T)
    assert T % block_t == 0, f"T={T} % block_t={block_t}"
    grid = (T // block_t,)
    w, ids = pl.pallas_call(
        functools.partial(_gating_kernel, k=k, n_experts=E),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
    return w, ids
