"""CycleSL fused resample-gather + server-head loss — Pallas TPU kernel.

The server inner loop's hot path is gather-then-loss: resample a
minibatch of pooled rows (Eq. 3), push it through the server head, take
the cross-entropy.  Dispatched separately, the gathered [sb, D] batch
round-trips HBM between the two (the gather kernel writes it, the loss
matmul reads it back) — so across one server epoch D_S^f is effectively
read twice per step.  This kernel fuses them: the same scalar-prefetch
grid as ``feature_resample`` streams ONE source row-block per output
block straight into the head matmul + log-softmax, so the gathered
batch never materializes and the pool is read exactly once per epoch.

Head model: a flattened linear head ``logits = f @ w (+ b)`` with
integer cross-entropy labels — the StageModel zoo's final stage (the
paper's CNN/MLP heads are all bias-free flatten-matmuls; an optional
bias is supported for generality).  The per-row labels ride the scalar
prefetch next to the plan indices, so the label gather is fused too.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_loss_kernel(idx_ref, y_ref, src_ref, w_ref, b_ref, out_ref):
    # the source row-block was selected by the index_map (idx_ref[i]);
    # head matmul + stable log-softmax + label pick in one VMEM pass
    i = pl.program_id(0)
    f = src_ref[...].astype(jnp.float32)                    # [1, D]
    logits = f @ w_ref[...].astype(jnp.float32)             # [1, K]
    logits = logits + b_ref[...].astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    ll = z - jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))
    # one-hot label pick — vector select instead of a dynamic scalar
    # gather (VPU-friendly; y is a prefetched SMEM scalar)
    y = y_ref[i]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, ll.shape, 1) == y)
    out_ref[...] = -jnp.sum(jnp.where(onehot, ll, 0.0), axis=-1,
                            keepdims=True)


def gather_loss_microbatch(src, labels, idx, w, b: Optional[jax.Array] = None,
                           *, interpret: bool = True):
    """Per-row fused gather + linear-head cross-entropy.

    ``out[i] = xent(src[idx[i]] @ w (+ b), labels[idx[i]])`` — src
    [T, D], labels [T] int, idx [M] int32, w [D, K], b [K] or None.
    Returns the per-row losses [M] float32 (the caller owns the
    microbatch mean).  Like ``feature_resample``, rows_per_block=1 keeps
    the index_map exact: each output row streams its own source row.
    """
    T, D = src.shape
    K = w.shape[1]
    M = idx.shape[0]
    if b is None:
        b = jnp.zeros((K,), jnp.float32)
    yv = jnp.take(labels, idx.astype(jnp.int32), axis=0).astype(jnp.int32)
    out = pl.pallas_call(
        _gather_loss_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(M,),
            in_specs=[
                pl.BlockSpec((1, D), lambda i, idx_ref, y_ref: (idx_ref[i], 0)),
                pl.BlockSpec((D, K), lambda i, idx_ref, y_ref: (0, 0)),
                pl.BlockSpec((K,), lambda i, idx_ref, y_ref: (0,)),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda i, idx_ref, y_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, 1), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), yv, src, w, b)
    return out[:, 0]
