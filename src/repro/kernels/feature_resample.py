"""CycleSL feature-resampling gather — Pallas TPU kernel.

The server's resampled mini-batches (paper Eq. 3) are a permutation
row-gather over the pooled smashed-data array.  XLA lowers ad-hoc
gathers with index broadcasting; on TPU the efficient idiom is a
*scalar-prefetch* grid: the permutation indices sit in SMEM, and the
source BlockSpec's index_map reads idx[i] to stream exactly one source
row-block per output row-block from HBM into VMEM — a pure
memory-bound copy at HBM bandwidth, no index arithmetic on the VPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, src_ref, out_ref):
    # whole row-block is selected by the index_map; plain copy here.
    out_ref[...] = src_ref[...]


def feature_resample(src, idx, *, rows_per_block: int = 1,
                     interpret: bool = True):
    """out[i] = src[idx[i]].  src [T, D], idx [M] int32 -> [M, D].

    rows_per_block=1 keeps the index_map exact (each output row streams
    its own source row); D is the VMEM tile width.
    """
    T, D = src.shape
    M = idx.shape[0]
    grid = (M,)
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, D), lambda i, idx_ref: (idx_ref[i], 0)),
            ],
            out_specs=pl.BlockSpec((1, D), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, D), src.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), src)
