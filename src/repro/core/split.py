"""Split-model abstraction: θ_CS = θ_S ∘ θ_C with an explicit cut.

A :class:`SplitTask` packages the five functions every SL algorithm in
this repo consumes.  Builders wrap (a) the paper's StageModel zoo
(CNN/LSTM/MLP) and (b) the big assigned transformer archs cut at
``cfg.cut_layers`` (+ whisper at the enc/dec boundary).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.cnn import StageModel
from repro.models.transformer import Transformer, block_kind
from repro.utils.tree import tree_slice


@dataclass(frozen=True)
class SplitTask:
    """The split-learning contract (paper Eq. 1)."""

    name: str
    init_client: Callable[[Any], Any]                 # key -> θ_C
    init_server: Callable[[Any], Any]                 # key -> θ_S
    client_forward: Callable[[Any, Any], Any]         # (θ_C, x) -> features
    server_apply: Callable[[Any, Any], Any]           # (θ_S, f) -> outputs
    loss: Callable[[Any, Any], jnp.ndarray]           # (outputs, y) -> scalar
    metrics: Callable[[Any, Any], dict]               # (outputs, y) -> dict
    # optional: extract the server head's [D_flat, K] weight matrix from
    # θ_S when the WHOLE server is one bias-free flatten-matmul + xent
    # (the StageModel zoo's final stage at the last cut).  Set iff
    # ``server_loss(sp, f, y) == xent(flatten(f) @ server_head(sp), y)``
    # exactly — the contract the fused gather+loss kernel
    # (CycleConfig.fused_gather_loss) relies on; None disables fusion.
    server_head: Any = None                           # (θ_S) -> w, or None

    # -------- derived --------
    def server_loss(self, sp, features, y):
        return self.loss(self.server_apply(sp, features), y)

    def e2e_loss(self, cp, sp, x, y):
        return self.server_loss(sp, self.client_forward(cp, x), y)

    def predict(self, cp, sp, x):
        return self.server_apply(sp, self.client_forward(cp, x))


# --------------------------------------------------------------- losses
def xent_loss(logits, y):
    ll = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(ll, y[..., None], axis=-1))


def xent_metrics(logits, y):
    pred = jnp.argmax(logits, axis=-1)
    return {"accuracy": jnp.mean((pred == y).astype(jnp.float32))}


def mse_loss(pred, y):
    return jnp.mean(jnp.square(pred.astype(jnp.float32) - y))


def mse_metrics(pred, y):
    # angular-distance analog used by the paper's gaze task
    p = pred / (jnp.linalg.norm(pred, axis=-1, keepdims=True) + 1e-8)
    t = y / (jnp.linalg.norm(y, axis=-1, keepdims=True) + 1e-8)
    cos = jnp.clip(jnp.sum(p * t, axis=-1), -1, 1)
    return {"angular_deg": jnp.mean(jnp.degrees(jnp.arccos(cos)))}


# ---------------------------------------------------- StageModel builder
def make_stage_task(model: StageModel, cut: int, kind: str = "xent",
                    name: str | None = None) -> SplitTask:
    """Split a StageModel at stage index ``cut`` (paper's block-wise cut)."""
    assert 0 < cut < model.n_stages, f"cut {cut} out of range"
    loss, metrics = ((xent_loss, xent_metrics) if kind == "xent"
                     else (mse_loss, mse_metrics))

    def init_client(key):
        full = model.init(key)
        return full[:cut]

    def init_server(key):
        full = model.init(key)
        return full[cut:]

    def client_forward(cp, x):
        return model.apply_range(cp, x, 0, cut)

    def server_apply(sp, f):
        x = f
        for i in range(cut, model.n_stages):
            x = model.stages[i][1](sp[i - cut], x)
        return x

    # fused gather+loss contract: when the entire server half is the
    # model's final flatten-matmul head (last-cut split, xent), expose
    # its weight matrix so the inner loop can fuse gather and loss
    server_head = None
    if (kind == "xent" and cut == model.n_stages - 1
            and getattr(model, "head_is_linear", False)):
        server_head = lambda sp: jax.tree.leaves(sp[-1])[0]

    return SplitTask(name or f"{model.name}@cut{cut}",
                     init_client, init_server, client_forward,
                     server_apply, loss, metrics, server_head=server_head)


# -------------------------------------------------- Transformer builder
def make_transformer_task(cfg: ArchConfig) -> SplitTask:
    """Cut a decoder-only arch after ``cfg.cut_layers`` blocks.

    θ_C = embedding + blocks[:cut] (the smashed data is the block-`cut`
    activation); θ_S = blocks[cut:] + final norm + head.  Labels are the
    next-token ids; the server also owns the MoE aux losses.
    """
    cut = cfg.cut_layers
    kind = block_kind(cfg)

    def init_client(key):
        p = Transformer.init(key, cfg)
        out = {"embed": p["embed"], "blocks": tree_slice(p["blocks"], 0, cut)}
        return out

    def init_server(key):
        p = Transformer.init(key, cfg)
        out = {"blocks": tree_slice(p["blocks"], cut, None),
               "final_norm": p["final_norm"]}
        if not cfg.tie_embeddings:
            out["lm_head"] = p["lm_head"]
        else:
            out["embed"] = p["embed"]    # unembedding copy server-side
        if kind == "hybrid":
            out["shared_attn"] = p["shared_attn"]
        return out

    def client_forward(cp, batch):
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        patch = batch.get("patch_embeds") if isinstance(batch, dict) else None
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = Transformer.embed_inputs(cp, cfg, tokens, patch)
        x, _ = Transformer.stack_forward(cp, cfg, x, positions,
                                         first_block=0, n_blocks=cut)
        return x

    def server_apply(sp, features):
        """Returns final hidden states + MoE aux; the loss computes the
        cross-entropy CHUNKED from hidden so [S, vocab] logits are never
        materialized (perf iteration 4, §Perf)."""
        B, S = features.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, metrics = Transformer.stack_forward(
            sp, cfg, features, positions,
            first_block=cut, n_blocks=cfg.n_layers - cut)
        return {"hidden": x, "aux": metrics, "params": sp}

    def loss(outputs, labels):
        nll, _ = Transformer.chunked_lm_loss(
            outputs["params"], cfg, outputs["hidden"], labels)
        if cfg.moe is not None:
            nll = (nll + cfg.moe.aux_weight * outputs["aux"]["aux_loss"]
                   + cfg.moe.router_z_weight * outputs["aux"]["z_loss"])
        return nll

    def metrics(outputs, labels):
        _, acc = Transformer.chunked_lm_loss(
            outputs["params"], cfg, outputs["hidden"], labels)
        return {"accuracy": acc}

    return SplitTask(f"{cfg.name}@cut{cut}", init_client, init_server,
                     client_forward, server_apply, loss, metrics)
