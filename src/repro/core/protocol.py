"""Round/entity state containers shared by every SL algorithm.

Each *entity* (the server, or one client) owns params + its own
optimizer state + step counter.  CycleSL's "standalone higher-level
task" framing (paper §3.1) requires the server optimizer to be fully
independent of the clients' — so the optimizer state lives here, per
entity, not in a global trainer.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.optim.optimizer import apply_updates


class EntityState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray            # int32 scalar


def init_entity(params, opt: Optimizer) -> EntityState:
    return EntityState(params, opt.init(params), jnp.zeros((), jnp.int32))


def entity_step(entity: EntityState, grads, opt: Optimizer) -> EntityState:
    updates, new_opt = opt.update(grads, entity.opt_state, entity.params,
                                  entity.step)
    return EntityState(apply_updates(entity.params, updates), new_opt,
                       entity.step + 1)


def stack_entities(entities: list[EntityState]) -> EntityState:
    """Stack per-client EntityStates along a leading cohort dim (vmap-able)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *entities)


def entity_mean(stacked: EntityState) -> EntityState:
    """FedAvg-style aggregation over the leading cohort dim."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked)


def broadcast_entity(entity: EntityState, n: int) -> EntityState:
    """Replicate one entity state n times along a new leading dim."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), entity)


def take_entities(stacked: EntityState, idx) -> EntityState:
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), stacked)


def put_entities(stacked: EntityState, idx, values: EntityState) -> EntityState:
    return jax.tree.map(lambda x, v: x.at[idx].set(v), stacked, values)
