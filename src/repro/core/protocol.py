"""Round/entity state containers shared by every SL algorithm.

Each *entity* (the server, or one client) owns params + its own
optimizer state + step counter.  CycleSL's "standalone higher-level
task" framing (paper §3.1) requires the server optimizer to be fully
independent of the clients' — so the optimizer state lives here, per
entity, not in a global trainer.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.optim.optimizer import apply_updates


class EntityState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray            # int32 scalar


def init_entity(params, opt: Optimizer) -> EntityState:
    return EntityState(params, opt.init(params), jnp.zeros((), jnp.int32))


def entity_step(entity: EntityState, grads, opt: Optimizer) -> EntityState:
    apply = getattr(opt, "apply", None)
    if apply is not None:
        # fused path (e.g. the Pallas fused-Adam kernel): one pass that
        # produces new params + new optimizer state directly
        new_params, new_opt = apply(grads, entity.opt_state, entity.params,
                                    entity.step)
        return EntityState(new_params, new_opt, entity.step + 1)
    updates, new_opt = opt.update(grads, entity.opt_state, entity.params,
                                  entity.step)
    return EntityState(apply_updates(entity.params, updates), new_opt,
                       entity.step + 1)


def stack_entities(entities: list[EntityState]) -> EntityState:
    """Stack per-client EntityStates along a leading cohort dim (vmap-able)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *entities)


def entity_mean(stacked: EntityState) -> EntityState:
    """FedAvg-style aggregation over the leading cohort dim.

    Dtype-preserving: the int32 ``step`` counter stays int32 (its mean is
    exactly integral — every cohort member stepped once), so the state's
    avals are stable round-over-round and the jitted round never retraces.
    """
    return jax.tree.map(lambda x: jnp.mean(x, axis=0).astype(x.dtype),
                        stacked)


def broadcast_entity(entity: EntityState, n: int) -> EntityState:
    """Replicate one entity state n times along a new leading dim."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), entity)


def take_entities(stacked: EntityState, idx) -> EntityState:
    # mode="clip": padded cohort slots carry the OOB sentinel id N; clamping
    # reads *some* valid client (the result is masked out downstream) instead
    # of the NaN fill that would poison masked arithmetic.
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0, mode="clip"),
                        stacked)


def put_entities(stacked: EntityState, idx, values: EntityState) -> EntityState:
    # mode="drop": scatter writes at OOB indices are discarded, so padded
    # cohort slots (sentinel id N) are structural no-ops.
    return jax.tree.map(lambda x, v: x.at[idx].set(v, mode="drop"),
                        stacked, values)


def masked_axis0_mean(x, mask):
    """Masked, dtype-preserving mean over the leading axis: rows with
    mask 0 contribute exact zeros and are excluded from the count.  With
    an all-ones mask this is bit-identical to ``jnp.mean(x, axis=0)``
    (appending exact zeros to a sum and dividing by the same count
    changes nothing)."""
    mb = mask.reshape((-1,) + (1,) * (x.ndim - 1))
    return (jnp.sum(jnp.where(mb > 0, x, 0), axis=0) / jnp.sum(mask)
            ).astype(x.dtype)


def masked_entity_mean(stacked: EntityState, mask) -> EntityState:
    """FedAvg over the live slots only: ``mask`` is [C] with 1.0 for live
    cohort members, 0.0 for padded slots."""
    return jax.tree.map(lambda x: masked_axis0_mean(x, mask), stacked)


def select_entities(mask, new: EntityState, old: EntityState) -> EntityState:
    """Per-slot select over stacked entities: live slots (mask 1) take
    ``new``, padded slots keep ``old``.  ``mask`` is [C] (or a scalar,
    for use inside a scan body)."""
    m = jnp.asarray(mask)

    def one(n, o):
        mb = m.reshape(m.shape + (1,) * (n.ndim - m.ndim))
        return jnp.where(mb > 0, n, o)

    return jax.tree.map(one, new, old)
