"""Client-drift / gradient-stability bookkeeping (paper Table 6).

The paper records the norms of the gradients the server sends back to
clients, averaged inside mini-batch, with mean and std over SL epochs
and clients.  Round metrics already carry ``feat_grad_norm_*``; this
accumulator aggregates them across a whole run.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class GradStabilityTracker:
    means: list[float] = field(default_factory=list)
    stds: list[float] = field(default_factory=list)

    def update(self, metrics: dict):
        # keep the device scalars as-is: a float() here would block the
        # host on every round's metrics, defeating the Engine's
        # sync_every device-resident cadence.  summary() reads them all
        # in one transfer at the end of the run.
        self.means.append(metrics["feat_grad_norm_mean"])
        self.stds.append(metrics["feat_grad_norm_std"])

    def summary(self) -> dict:
        import jax
        means, stds = jax.device_get((self.means, self.stds))
        self.means = [float(v) for v in means]
        self.stds = [float(v) for v in stds]
        m = np.asarray(self.means)
        return {
            "grad_norm_mean": float(m.mean()) if len(m) else float("nan"),
            "grad_norm_std_over_rounds": float(m.std()) if len(m) else float("nan"),
            "grad_norm_within_batch_std": float(np.mean(self.stds)) if self.stds else float("nan"),
        }
