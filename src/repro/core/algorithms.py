"""The SL algorithm zoo the paper benchmarks (§4) + the Cycle variants.

All algorithms share one interface so the benchmark harness treats them
uniformly:

    algo = make_algorithm("cyclesfl", task, opt_server=..., opt_client=...)
    state = algo.init(key, n_clients)
    state, metrics = algo.round(state, cohort_idx, xs, ys, key)

Semantics (paper §2.1 / §4):

  ssl       sequential SL: one shared client model passed client-to-client,
            end-to-end update per client (the O(N)-latency canon).
  psl       parallel SL: per-pair end-to-end steps against server model
            replicas, server replicas averaged; clients NEVER aggregated.
  sflv1     PSL + FedAvg of client models (SplitFed V1).
  sflv2     single server model, clients processed sequentially on the
            server side; client models aggregated (SplitFed V2).
  sglr      single server updated with the cohort-mean gradient; the
            returned feature gradients are averaged over the cohort
            (server-side local gradient averaging) — no model aggregation.
  fedavg    clients train the FULL composed model locally; average.
  cyclepsl  CycleSL plugged into PSL    (== paper Algorithm 1).
  cyclesfl  CycleSL plugged into SFL    (client models aggregated at round end).
  cyclesglr CycleSL plugged into SGLR   (averaged feature grads).
  cyclessl  CycleSL on sequential SL    (appendix-only in the paper).

PSL-family keeps a *persistent per-client* model store (cold-start /
lag effects included, as in the paper); SFL-family keeps one global
client model all cohort members start from.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.cyclesl import (CycleConfig, client_updates, cyclesl_round,
                                feature_gradients)
from repro.core.protocol import (EntityState, broadcast_entity, entity_mean,
                                 entity_step, init_entity, put_entities,
                                 take_entities)
from repro.core.split import SplitTask
from repro.optim import Optimizer, adam


class AlgoState(NamedTuple):
    server: EntityState
    clients: Optional[EntityState]        # stacked [N, ...] (PSL-family)
    client_global: Optional[EntityState]  # shared θ_C (SFL-family / fedavg)


@dataclass(frozen=True)
class SLAlgorithm:
    name: str
    init: Callable[..., AlgoState]
    round: Callable[..., tuple[AlgoState, dict]]
    uses_global_client: bool


def _feat_metrics(fgrads):
    fg = fgrads.reshape(fgrads.shape[0], -1).astype(jnp.float32)
    norms = jnp.linalg.norm(fg, axis=-1) / jnp.sqrt(fg.shape[-1])
    return {"feat_grad_norm_mean": jnp.mean(norms),
            "feat_grad_norm_std": jnp.std(norms)}


def make_algorithm(name: str, task: SplitTask, opt_server: Optimizer,
                   opt_client: Optimizer,
                   cycle: CycleConfig = CycleConfig()) -> SLAlgorithm:
    name = name.lower()
    if name not in ALGORITHMS:
        raise KeyError(f"unknown algorithm {name!r}: {sorted(ALGORITHMS)}")
    if name == "cyclesglr":
        from dataclasses import replace
        cycle = replace(cycle, avg_client_grads=True)
    return ALGORITHMS[name](name, task, opt_server, opt_client, cycle)


# ------------------------------------------------------------------ init
def _init_state(key, n_clients: int, task: SplitTask, opt_s, opt_c,
                global_client: bool) -> AlgoState:
    ks, kc = jax.random.split(key)
    server = init_entity(task.init_server(ks), opt_s)
    client0 = init_entity(task.init_client(kc), opt_c)
    if global_client:
        return AlgoState(server, None, client0)
    # per-client persistent models — identical init (the paper initializes
    # every client the same way; heterogeneity comes from the data)
    n = n_clients
    return AlgoState(server, broadcast_entity(client0, n), None)


# --------------------------------------------------------------- helpers
def _pair_losses_and_grads(task, server_params, client_params, xs, ys):
    """vmap end-to-end loss/grads over cohort pairs."""
    def one(cp, x, y):
        def loss_fn(c, s):
            return task.e2e_loss(c, s, x, y)
        loss, (gc, gs) = jax.value_and_grad(loss_fn, (0, 1))(cp, server_params)
        # the gradient actually *sent back* over the wire is dL/d features
        f = task.client_forward(cp, x)
        fg = jax.grad(lambda ff: task.server_loss(
            jax.lax.stop_gradient(server_params), ff, y))(f)
        return loss, gc, gs, fg
    return jax.vmap(one)(client_params, xs, ys)


# ------------------------------------------------------------------- PSL
def _psl_round(task, opt_s, opt_c, cycle, state: AlgoState, cohort,
               xs, ys, key, aggregate_clients: bool):
    cohort_clients = (broadcast_entity(state.client_global, xs.shape[0])
                      if state.clients is None
                      else take_entities(state.clients, cohort))
    losses, gc, gs, fg = _pair_losses_and_grads(
        task, state.server.params, cohort_clients.params, xs, ys)
    # per-pair server replica step, then replica averaging (model agg.)
    rep = broadcast_entity(state.server, xs.shape[0])
    rep = jax.vmap(lambda e, g: entity_step(e, g, opt_s))(rep, gs)
    server = entity_mean(rep)
    # client local steps
    cohort_clients = jax.vmap(lambda e, g: entity_step(e, g, opt_c))(
        cohort_clients, gc)
    metrics = {"server_loss": jnp.mean(losses), **_feat_metrics(fg)}
    state = _commit_clients(state, cohort, cohort_clients, aggregate_clients)
    return AlgoState(server, state.clients, state.client_global), metrics


def _commit_clients(state: AlgoState, cohort, cohort_clients,
                    aggregate: bool) -> AlgoState:
    if aggregate:
        return AlgoState(state.server, state.clients,
                         entity_mean(cohort_clients))
    return AlgoState(state.server,
                     put_entities(state.clients, cohort, cohort_clients),
                     state.client_global)


# ------------------------------------------------------------------ SGLR
def _sglr_round(task, opt_s, opt_c, cycle, state: AlgoState, cohort,
                xs, ys, key):
    cohort_clients = take_entities(state.clients, cohort)
    losses, gc, gs, fg = _pair_losses_and_grads(
        task, state.server.params, cohort_clients.params, xs, ys)
    # single server model, cohort-mean gradient (no duplication)
    server = entity_step(state.server, jax.tree.map(
        lambda g: jnp.mean(g, axis=0), gs), opt_s)
    # server-side local gradient averaging: every client receives the
    # cohort-mean feature gradient, pulled through its own VJP
    fg_mean = jnp.broadcast_to(jnp.mean(fg, axis=0, keepdims=True), fg.shape)
    cohort_clients, _ = client_updates(task, cohort_clients, opt_c, xs, fg_mean)
    metrics = {"server_loss": jnp.mean(losses), **_feat_metrics(fg_mean)}
    state = _commit_clients(state, cohort, cohort_clients, aggregate=False)
    return AlgoState(server, state.clients, state.client_global), metrics


# ----------------------------------------------------------------- SFLV2
def _sflv2_round(task, opt_s, opt_c, cycle, state: AlgoState, cohort,
                 xs, ys, key):
    cohort_clients = broadcast_entity(state.client_global, xs.shape[0])

    def body(server, inp):
        cp, x, y = inp
        def loss_fn(c, s):
            return task.e2e_loss(c, s, x, y)
        loss, (gc, gs) = jax.value_and_grad(loss_fn, (0, 1))(cp, server.params)
        f = task.client_forward(cp, x)
        fg = jax.grad(lambda ff: task.server_loss(
            jax.lax.stop_gradient(server.params), ff, y))(f)
        return entity_step(server, gs, opt_s), (loss, gc, fg)

    server, (losses, gc, fg) = jax.lax.scan(
        body, state.server, (cohort_clients.params, xs, ys))
    cohort_clients = jax.vmap(lambda e, g: entity_step(e, g, opt_c))(
        cohort_clients, gc)
    metrics = {"server_loss": jnp.mean(losses), **_feat_metrics(fg)}
    return AlgoState(server, state.clients, entity_mean(cohort_clients)), metrics


# ------------------------------------------------------------------- SSL
def _ssl_round(task, opt_s, opt_c, cycle, state: AlgoState, cohort,
               xs, ys, key):
    """Sequential SL: client model passed along the cohort chain."""

    def body(carry, inp):
        server, client = carry
        x, y = inp
        def loss_fn(c, s):
            return task.e2e_loss(c, s, x, y)
        loss, (gc, gs) = jax.value_and_grad(loss_fn, (0, 1))(
            client.params, server.params)
        f = task.client_forward(client.params, x)
        fg = jax.grad(lambda ff: task.server_loss(
            jax.lax.stop_gradient(server.params), ff, y))(f)
        return ((entity_step(server, gs, opt_s),
                 entity_step(client, gc, opt_c)), (loss, fg))

    (server, client), (losses, fg) = jax.lax.scan(
        body, (state.server, state.client_global), (xs, ys))
    metrics = {"server_loss": jnp.mean(losses), **_feat_metrics(fg)}
    return AlgoState(server, state.clients, client), metrics


# ---------------------------------------------------------------- FedAvg
def _fedavg_round(task, opt_s, opt_c, cycle, state: AlgoState, cohort,
                  xs, ys, key):
    """Clients train the full composed model locally; average both parts."""
    n = xs.shape[0]
    servers = broadcast_entity(state.server, n)
    clients = broadcast_entity(state.client_global, n)

    def one(se, ce, x, y):
        def loss_fn(c, s):
            return task.e2e_loss(c, s, x, y)
        loss, (gc, gs) = jax.value_and_grad(loss_fn, (0, 1))(ce.params, se.params)
        return entity_step(se, gs, opt_s), entity_step(ce, gc, opt_c), loss

    servers, clients, losses = jax.vmap(one)(servers, clients, xs, ys)
    return (AlgoState(entity_mean(servers), state.clients, entity_mean(clients)),
            {"server_loss": jnp.mean(losses),
             "feat_grad_norm_mean": jnp.zeros(()),
             "feat_grad_norm_std": jnp.zeros(())})


# --------------------------------------------------------- Cycle variants
def _cycle_round(task, opt_s, opt_c, cycle: CycleConfig, state: AlgoState,
                 cohort, xs, ys, key, aggregate_clients: bool):
    cohort_clients = (broadcast_entity(state.client_global, ys.shape[0])
                      if state.clients is None
                      else take_entities(state.clients, cohort))
    server, cohort_clients, metrics = cyclesl_round(
        task, state.server, cohort_clients, opt_s, opt_c, xs, ys, key, cycle)
    state = AlgoState(server, state.clients, state.client_global)
    state = _commit_clients(state, cohort, cohort_clients, aggregate_clients)
    return state, metrics


def _cyclessl_round(task, opt_s, opt_c, cycle, state, cohort, xs, ys, key):
    """CycleSL on the sequential chain: one client model, features from the
    chain, then the standard CycleSL server phase + one chained update."""
    # extract features sequentially with the single client model
    feats = jax.vmap(lambda x: task.client_forward(state.client_global.params, x))(xs)
    from repro.core.feature_store import FeatureStore
    from repro.core.cyclesl import server_inner_loop
    store = FeatureStore.pool(jax.lax.stop_gradient(feats), ys)
    server, sloss = server_inner_loop(task, state.server, opt_s, store, key,
                                      cycle, batch=ys.shape[1])
    fgrads = feature_gradients(task, server.params, feats, ys, cycle)

    def body(client, inp):
        x, g = inp
        def fwd(p):
            return task.client_forward(p, x)
        out, vjp = jax.vjp(fwd, client.params)
        (grads,) = vjp(g.astype(out.dtype))
        return entity_step(client, grads, opt_c), None

    client, _ = jax.lax.scan(body, state.client_global, (xs, fgrads))
    metrics = {"server_loss": sloss, **_feat_metrics(fgrads),
               "client_grad_norm_mean": jnp.zeros(())}
    return AlgoState(server, state.clients, client), metrics


# --------------------------------------------------------------- registry
def _make(round_fn, global_client: bool):
    def build(name, task, opt_s, opt_c, cycle):
        def init(key, n_clients: int) -> AlgoState:
            return _init_state(key, n_clients, task, opt_s, opt_c, global_client)

        @jax.jit
        def round(state, cohort, xs, ys, key):
            return round_fn(task, opt_s, opt_c, cycle, state, cohort, xs, ys, key)

        return SLAlgorithm(name, init, round, global_client)
    return build


ALGORITHMS: dict[str, Callable] = {
    "ssl": _make(_ssl_round, True),
    "psl": _make(partial(_psl_round, aggregate_clients=False), False),
    "sflv1": _make(partial(_psl_round, aggregate_clients=True), True),
    "sflv2": _make(_sflv2_round, True),
    "sglr": _make(_sglr_round, False),
    "fedavg": _make(_fedavg_round, True),
    "cyclepsl": _make(partial(_cycle_round, aggregate_clients=False), False),
    "cyclesfl": _make(partial(_cycle_round, aggregate_clients=True), True),
    "cyclesglr": _make(partial(_cycle_round, aggregate_clients=False), False),
    "cyclessl": _make(_cyclessl_round, True),
}
