"""The SL algorithm zoo — now a thin compatibility shim over ``repro.api``.

All algorithms share one interface so the benchmark harness treats them
uniformly:

    algo = make_algorithm("cyclesfl", task, opt_server=..., opt_client=...)
    state = algo.init(key, n_clients)
    state, metrics = algo.round(state, cohort_idx, xs, ys, key)

The round implementations themselves live in :mod:`repro.api.phases` as
declarative :class:`~repro.api.phases.RoundProgram` compositions — see
:mod:`repro.api.registry` for the name -> program table and the
semantics of each variant (paper §2.1 / §4):

  ssl       sequential SL (O(N)-latency canon)
  psl       parallel SL, server replicas averaged, clients never aggregated
  sflv1     PSL + FedAvg of client models (SplitFed V1)
  sflv2     single server, clients processed sequentially server-side
  sglr      server-side local gradient averaging (no model aggregation)
  fedavg    full-model local training + averaging (non-SL yardstick)
  cyclepsl  CycleSL plugged into PSL    (== paper Algorithm 1)
  cyclesfl  CycleSL plugged into SFL
  cyclesglr CycleSL plugged into SGLR
  cyclessl  CycleSL on sequential SL    (appendix-only in the paper)

PSL-family keeps a *persistent per-client* model store (cold-start /
lag effects included, as in the paper); SFL-family keeps one global
client model all cohort members start from.

Deprecated: new code should resolve programs through
``repro.api.get_program`` + ``build_algorithm``, or drive whole
experiments with ``repro.api.Engine``.
"""
from __future__ import annotations

import warnings

from repro.api.phases import (RoundProgram, SLAlgorithm,  # noqa: F401
                              TrainState, build_algorithm)
from repro.api.registry import PROGRAMS, get_program
from repro.core.cyclesl import CycleConfig
from repro.core.split import SplitTask
from repro.optim import Optimizer

# Backwards-compatible aliases: AlgoState is the same pytree the phases
# operate on, and ALGORITHMS resolves through the one program registry.
AlgoState = TrainState
ALGORITHMS: dict[str, RoundProgram] = PROGRAMS


def make_algorithm(name: str, task: SplitTask, opt_server: Optimizer,
                   opt_client: Optimizer,
                   cycle: CycleConfig = CycleConfig()) -> SLAlgorithm:
    """Deprecated shim: compile a registered RoundProgram.

    Use ``repro.api.build_algorithm(repro.api.get_program(name), ...)``
    (or ``repro.api.Engine`` for full runs) in new code.
    """
    warnings.warn(
        "make_algorithm is deprecated; use repro.api.get_program + "
        "build_algorithm, or repro.api.Engine",
        DeprecationWarning, stacklevel=2)
    return build_algorithm(get_program(name), task, opt_server, opt_client,
                           cycle)
