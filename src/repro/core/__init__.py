"""CycleSL core: split tasks, feature store, cyclical updates, SL zoo."""
from repro.core.split import SplitTask, make_stage_task, make_transformer_task
from repro.core.feature_store import (FeatureStore, masked_resample_plan,
                                      resample_plan)
from repro.core.cyclesl import cyclesl_round, CycleConfig
from repro.core.protocol import EntityState, init_entity
from repro.core.algorithms import make_algorithm, ALGORITHMS
