"""CycleSL round — paper Algorithm 1, as one pure (jit-able) function.

The round is the paper's contribution verbatim:

  1. clients extract features        B_i^f = θ_C_i(B_i^x)      (parallel)
  2. server pools a feature dataset  D_S^f = ⨄ B_i^f           (Eq. 3)
  3. server trains E epochs on resampled shuffled mini-batches  (Eq. 3)
  4. server FREEZES θ_S^{t+1} and computes feature gradients
     B_i^g = ∇_{B_i^f} L(θ_S^{t+1}(B_i^f))                     (Eq. 5)
  5. clients pull B_i^g through their local VJP and step        (Eq. 5)

Step 4 uses the *updated* server (the cyclical/BCD part) and
``stop_gradient`` walls guarantee no server parameter traces gradients
during the client phase — the memory argument of paper §5.2.

SGLR integration (CycleSGLR): feature gradients are averaged over the
cohort before being returned, and client/server learning rates are
decoupled (both handled by the caller via ``CycleConfig``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.feature_store import (FeatureStore, gather_batch,
                                      masked_resample_plan, pool_store,
                                      resample_plan, shard_local_fused_loss,
                                      shard_local_gather)
from repro.core.protocol import (EntityState, entity_step, masked_axis0_mean,
                                 select_entities)
from repro.core.split import SplitTask
from repro.optim import Optimizer, clip_by_global_norm


def _maybe_clip(grads, max_norm: Optional[float]):
    """Global-norm clipping when ``max_norm`` is set (CycleConfig.grad_clip)."""
    if max_norm is None:
        return grads
    clipped, _ = clip_by_global_norm(grads, max_norm)
    return clipped


@dataclass(frozen=True)
class CycleConfig:
    server_epochs: int = 1          # E in Algorithm 1 (Table 5 ablation)
    server_batch: Optional[int] = None  # default: the client batch size b
    # cap on resampled minibatch STEPS per epoch (None = full coverage of
    # D_S^f).  Algorithm 1's inner loop reads as one resampled batch per
    # server epoch; server_steps=1 gives that literal variant, None gives
    # the epoch reading implied by the paper's Table 8 server cost.
    server_steps: Optional[int] = None
    avg_client_grads: bool = False  # CycleSGLR: SGLR-style grad averaging
    # global-norm clip applied to every server inner-loop step and every
    # client VJP step (None = no clipping)
    grad_clip: Optional[float] = None
    # shard-LOCAL resample: route the server inner loop's gather through
    # the shard_map wrapper (per-shard index translation + masked
    # cross-shard fixup) instead of letting GSPMD gather the pooled
    # operand around the kernel.  Value-exact (bit-for-bit the GSPMD
    # path); only meaningful when the round runs on a mesh.
    shard_local_resample: bool = False
    # force the Pallas resample kernel on (True, interpret off-TPU) or
    # off (False, jnp.take); None = backend default (kernel on TPU).
    # This is the config-resolved choice gather_batch receives inside
    # the inner loop — tests and CPU users can pin either path.
    resample_use_kernel: Optional[bool] = None
    # fuse the resample gather with the server head's logits/loss
    # (kernels/gather_loss.py) so the gathered minibatch never
    # materializes and D_S^f is read once per epoch.  Engages only for
    # tasks exposing a linear head (SplitTask.server_head) with plain
    # integer labels; ignored (with the classic path kept) otherwise,
    # and superseded by shard_local_resample on a mesh.
    fused_gather_loss: bool = False
    # NOTE: the old ``batch_constraint`` callable hook is gone — server
    # batch sharding now flows from the mesh itself (the serializable
    # ``ExperimentConfig.mesh_shape`` knobs / the launcher's mesh) via
    # ``sharding.specs.constrain_server_batch``, threaded through the
    # ``mesh`` argument of :func:`server_inner_loop`.


def server_inner_loop(task: SplitTask, server: EntityState, opt_s: Optimizer,
                      store: FeatureStore, key, ccfg: CycleConfig,
                      batch: int, mesh=None,
                      grad_scale=None) -> tuple[EntityState, jnp.ndarray]:
    """E epochs of minibatch training on the resampled feature dataset.

    When the store carries a row-validity mask (padded cohort), the plan
    comes from :func:`masked_resample_plan`: the scan always runs the
    static capacity's worth of steps, but steps whose rows are not all
    live are exact no-ops (the entity passes through unchanged, the loss
    is excluded from the mean) — so one compiled loop serves every live
    cohort size, with numerics identical to an unpadded pool of just the
    live rows.

    ``mesh`` pins every resampled minibatch data-parallel over the batch
    axes (:func:`repro.sharding.specs.constrain_server_batch`); the
    gather itself dispatches to the ``feature_resample`` Pallas kernel
    on TPU, with ``ccfg.resample_use_kernel`` as the explicit override
    (see :func:`gather_batch`).  ``ccfg.shard_local_resample`` + mesh
    routes the gather through :func:`shard_local_gather` instead — the
    shard_map wrapper whose per-shard index translation keeps the
    resample shard-LOCAL (bit-for-bit the GSPMD path).
    ``ccfg.fused_gather_loss`` additionally fuses gather and head loss
    through ``kernels.ops.fused_gather_loss_mean`` when the task
    exposes a linear server head.  ``mesh=None`` leaves placement to
    GSPMD — layout only, never values.  ``grad_scale`` (a traced scalar,
    or None) multiplies every clipped gradient before the optimizer
    step — the staleness-weighting hook; 1.0 is an exact no-op.
    """
    sb = min(ccfg.server_batch or batch, store.size)
    shard_local = ccfg.shard_local_resample and mesh is not None
    # minibatch layout: tensor-parallel (replicated rows) when the
    # server params are FSDP/TP-sharded on this mesh — row-sharding the
    # batch on the same axis as the weights forces a full weight
    # all-gather per scan step; data-parallel (rows over 'data') when
    # the weights are replicated.  Static (shapes + path rules only).
    if mesh is not None:
        from repro.sharding.specs import params_are_sharded
        tp_layout = params_are_sharded(server.params, mesh, "server")
    else:
        tp_layout = False
    # fused path: linear head + single integer label leaf.  On a sharded
    # mesh this composes with the shard-local resample through
    # shard_local_fused_loss — the per-row loss runs INSIDE the
    # shard_map body over each shard's pool slice and only a scalar
    # psum crosses devices, so the fused kernel no longer reintroduces
    # the feature-pool all-gather the shard-local route exists to avoid.
    fused = (ccfg.fused_gather_loss
             and getattr(task, "server_head", None) is not None
             and isinstance(store.labels, jax.Array)
             and jnp.issubdtype(store.labels.dtype, jnp.integer))
    if store.valid is None:
        plan = resample_plan(key, store.size, ccfg.server_epochs, sb)
        step_ok = None
    else:
        plan, step_ok = masked_resample_plan(key, store.valid,
                                             ccfg.server_epochs, sb)
    if ccfg.server_steps is not None:
        plan = plan[:, : ccfg.server_steps]
        if step_ok is not None:
            step_ok = step_ok[:, : ccfg.server_steps]
    plan2 = plan.reshape(-1, sb)                     # [E*steps, sb]

    def fused_step_loss(params, idx):
        w = task.server_head(params)
        if shard_local:
            return shard_local_fused_loss(store, idx, w, mesh,
                                          use_kernel=ccfg.resample_use_kernel)
        from repro.kernels import ops
        return ops.fused_gather_loss_mean(
            store.features.reshape((store.size, -1)), store.labels, idx, w)

    def apply_step(entity, idx):
        if fused:
            loss, grads = jax.value_and_grad(fused_step_loss)(entity.params,
                                                              idx)
        else:
            if shard_local:
                f, y = shard_local_gather(store, idx, mesh,
                                          use_kernel=ccfg.resample_use_kernel,
                                          replicate_out=tp_layout)
            else:
                f, y = gather_batch(store, idx,
                                    use_kernel=ccfg.resample_use_kernel)
            if mesh is not None:
                from repro.sharding.specs import constrain_server_batch
                f, y = constrain_server_batch(f, y, mesh,
                                              replicate=tp_layout)
            loss, grads = jax.value_and_grad(task.server_loss)(entity.params,
                                                               f, y)
        grads = _maybe_clip(grads, ccfg.grad_clip)
        if grad_scale is not None:
            # staleness weighting: a traced scalar so one trace serves
            # every realized lag; scale == 1.0 is an exact no-op
            grads = jax.tree.map(lambda g: g * grad_scale, grads)
        return entity_step(entity, grads, opt_s), loss

    if step_ok is None:
        server, losses = jax.lax.scan(apply_step, server, plan2)
        return server, jnp.mean(losses)

    # the loss sum rides the scan carry: sequential accumulation (with
    # exact-zero no-ops for masked steps) is invariant to how much
    # padding follows the live steps, unlike a post-hoc jnp.sum whose
    # SIMD reduction tree depends on the array length
    def one_step(carry, inp):
        entity, acc = carry
        idx, ok = inp
        stepped, loss = apply_step(entity, idx)
        return ((select_entities(ok, stepped, entity),
                 acc + jnp.where(ok, loss, 0.0)), None)

    ok2 = step_ok.reshape(-1)
    (server, loss_sum), _ = jax.lax.scan(
        one_step, (server, jnp.zeros((), jnp.float32)), (plan2, ok2))
    denom = jnp.maximum(jnp.sum(ok2.astype(loss_sum.dtype)), 1.0)
    return server, loss_sum / denom


def feature_gradients(task: SplitTask, server_params, feats, ys,
                      ccfg: CycleConfig, mask=None, mesh=None):
    """B_i^g for every cohort member, with θ_S^{t+1} frozen (Eq. 5).

    ``mask`` ([C], 1.0 = live slot) restricts the SGLR-style cohort-mean
    to live slots so padded members neither contribute to nor dilute the
    averaged gradient.  With ``mesh`` set the per-slot grads run inside
    a shard_map (:func:`repro.sharding.specs.slot_shard_map`) so each
    device differentiates only its local slots.
    """
    frozen = jax.lax.stop_gradient(server_params)

    def per_client(f, y, sp):
        return jax.grad(lambda ff: task.server_loss(sp, ff, y))(f)

    from repro.sharding.specs import slot_shard_map
    grads = slot_shard_map(jax.vmap(per_client, in_axes=(0, 0, None)),
                           mesh, (feats, ys), (frozen,))  # [C, b, ...]
    if ccfg.avg_client_grads:
        mean = (jnp.mean(grads, axis=0) if mask is None
                else masked_axis0_mean(grads, mask))
        grads = jnp.broadcast_to(mean[None], grads.shape)
    return grads


def client_update_one(task: SplitTask, entity: EntityState, x, g,
                      opt_c: Optimizer,
                      grad_clip: Optional[float] = None
                      ) -> tuple[EntityState, jnp.ndarray]:
    """One client's phase-5 step: pull its feature gradient ``g`` through
    the local VJP, optionally clip, and take one optimizer step.

    The single source of truth for the client update — the cohort-vmapped
    :func:`client_updates` and the sequential (cyclessl) chain both call it.
    Returns the stepped entity and the global norm of the applied grads.
    """
    def fwd(p):
        return task.client_forward(p, x)
    out, vjp = jax.vjp(fwd, entity.params)
    (grads,) = vjp(g.astype(out.dtype))
    grads = _maybe_clip(grads, grad_clip)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                         for l in jax.tree.leaves(grads)))
    return entity_step(entity, grads, opt_c), gnorm


def client_updates(task: SplitTask, clients: EntityState, opt_c: Optimizer,
                   xs, feat_grads,
                   grad_clip: Optional[float] = None,
                   mask=None, mesh=None) -> tuple[EntityState, jnp.ndarray]:
    """Pull B_i^g through each client's VJP and take one optimizer step.

    With ``mask`` set, padded slots receive a zeroed update: their entity
    (params, optimizer state, step counter) passes through unchanged and
    their grad norm reads 0, so the commit phase's scatter/average sees
    no contribution from them.  With ``mesh`` set the per-slot VJPs run
    inside a shard_map (each device updates only its local slots).
    """
    from repro.sharding.specs import slot_shard_map
    new_clients, gnorms = slot_shard_map(jax.vmap(
        lambda e, x, g: client_update_one(task, e, x, g, opt_c, grad_clip)),
        mesh, (clients, xs, feat_grads))
    if mask is not None:
        new_clients = select_entities(mask, new_clients, clients)
        gnorms = jnp.where(mask > 0, gnorms, 0.0)
    return new_clients, gnorms


def cyclesl_extract(task: SplitTask, clients: EntityState, xs, ys,
                    mesh=None) -> tuple[jnp.ndarray, FeatureStore]:
    """Phases 1-2 of Algorithm 1 as a standalone dispatch: parallel
    client feature extraction plus the pooled D_S^f handoff (Eq. 3).

    This is the half of the round that lives on the cohort/batch axes —
    the pipelined schedule dispatches it for cohort k+1 while cohort k's
    :func:`cyclesl_tail` occupies the server/model axes.  Composing the
    two inside one trace is exactly the monolithic :func:`cyclesl_round`.
    Returns ``(feats, store)``.
    """
    from repro.sharding.specs import constrain_cohort, slot_shard_map
    feats = slot_shard_map(jax.vmap(task.client_forward), mesh,
                           (clients.params, xs))
    if mesh is not None:
        feats = constrain_cohort(feats, mesh)
    return feats, pool_store(feats, ys, mesh=mesh)


def cyclesl_tail(task: SplitTask, server: EntityState, clients: EntityState,
                 opt_s: Optimizer, opt_c: Optimizer, xs, ys, key,
                 ccfg: CycleConfig, feats, store: FeatureStore, mesh=None):
    """Phases 3-5 of Algorithm 1, consuming an extract handoff: server
    inner epochs on the pooled store, frozen-server feature gradients
    (Eq. 5), and the client VJP steps.  Returns (server', clients',
    metrics)."""
    batch = jax.tree.leaves(ys)[0].shape[1]
    server, server_loss = server_inner_loop(
        task, server, opt_s, store, key, ccfg, batch=batch, mesh=mesh)

    fgrads = feature_gradients(task, server.params, feats, ys, ccfg,
                               mesh=mesh)
    fg_flat = fgrads.reshape(fgrads.shape[0], -1).astype(jnp.float32)
    per_sample_norm = jnp.linalg.norm(
        fg_flat, axis=-1) / jnp.sqrt(fg_flat.shape[-1])

    clients, client_gnorms = client_updates(task, clients, opt_c, xs, fgrads,
                                            grad_clip=ccfg.grad_clip,
                                            mesh=mesh)

    metrics = {
        "server_loss": server_loss,
        "feat_grad_norm_mean": jnp.mean(per_sample_norm),
        "feat_grad_norm_std": jnp.std(per_sample_norm),
        "client_grad_norm_mean": jnp.mean(client_gnorms),
    }
    return server, clients, metrics


def cyclesl_round(task: SplitTask, server: EntityState,
                  clients: EntityState, opt_s: Optimizer, opt_c: Optimizer,
                  xs, ys, key, ccfg: CycleConfig, mesh=None):
    """One full CycleSL round (Algorithm 1).

    xs, ys: cohort-stacked batches [C, b, ...].
    clients: cohort-stacked EntityState.
    ``mesh`` shards the round end-to-end: cohort-stacked activations over
    the batch axes, the pooled feature dataset over 'data', and every
    resampled server minibatch data-parallel.
    Returns (server', clients', metrics).

    Implemented as extract ∘ tail so the monolithic round and the
    pipelined two-dispatch schedule share every op.
    """
    feats, store = cyclesl_extract(task, clients, xs, ys, mesh=mesh)
    return cyclesl_tail(task, server, clients, opt_s, opt_c, xs, ys, key,
                        ccfg, feats, store, mesh=mesh)
