"""The server-side global feature dataset + resampler (paper Eq. 3).

``D_S^f = ⨄_i B_i^f`` — client feature batches are pooled and the
server resamples *shuffled* mini-batches that are no longer client-
bound.  On a pod the pooled array stays sharded over the 'data' axis and
resampling is a sharded permutation-gather (the `feature_resample`
Pallas kernel covers the shard-local gather).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FeatureStore(NamedTuple):
    """Pooled smashed data: features [T, ...], labels pytree of [T, ...]."""
    features: jax.Array
    labels: jax.Array

    @classmethod
    def pool(cls, feature_batches, label_batches) -> "FeatureStore":
        """[C, b, ...] per-client batches -> pooled [C*b, ...].
        Labels may be any pytree of [C, b, ...] arrays."""
        merge = lambda a: a.reshape((-1,) + a.shape[2:])
        return cls(merge(feature_batches), jax.tree.map(merge, label_batches))

    @property
    def size(self) -> int:
        return self.features.shape[0]


def resample_plan(key, total: int, epochs: int, batch: int) -> jax.Array:
    """Index plan [epochs, steps, batch]: a fresh permutation per server
    epoch (random-reshuffling — the paper's analog of centralized
    shuffling, §3.1).  Truncates the tail that doesn't fill a batch."""
    steps = total // batch
    keys = jax.random.split(key, epochs)
    perms = jnp.stack([jax.random.permutation(k, total) for k in keys])
    return perms[:, : steps * batch].reshape(epochs, steps, batch)


def gather_batch(store: FeatureStore, idx) -> tuple[jax.Array, jax.Array]:
    return (jnp.take(store.features, idx, axis=0),
            jax.tree.map(lambda l: jnp.take(l, idx, axis=0), store.labels))
