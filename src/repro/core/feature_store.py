"""The server-side global feature dataset + resampler (paper Eq. 3).

``D_S^f = ⨄_i B_i^f`` — client feature batches are pooled and the
server resamples *shuffled* mini-batches that are no longer client-
bound.  On a pod the pooled array stays sharded over the 'data' axis and
resampling is a sharded permutation-gather (the `feature_resample`
Pallas kernel covers the shard-local gather).

Two resampling plans live here:

* :func:`resample_plan` — the classic dense plan (one
  ``jax.random.permutation`` per server epoch) used when every pooled
  row is live.
* :func:`masked_resample_plan` — the padded-cohort plan: rows are
  ordered by per-row counter-based uniforms (``fold_in(key, row)``),
  with padded rows pushed past the live ones.  Because each row's sort
  key depends only on ``(key, row_index)`` — never on the pool's padded
  capacity — the sequence of live rows it yields is *identical* for any
  capacity ≥ the live count.  That shape-invariance is what makes the
  padded round bit-for-bit equal to the unpadded one (tests/test_padded).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


def valid_from_mask(mask, batch: int) -> jax.Array:
    """Broadcast a [C] cohort attendance mask to the [C*b] per-row
    validity mask over the pooled feature axis.

    Zeros may sit ANYWHERE in ``mask`` — trailing padded slots, or live
    slots zeroed mid-round by scenario churn (dropouts / deadline-missed
    stragglers) — and the pooled validity inherits that interleaving.
    :func:`masked_resample_plan` already handles arbitrary interleaved
    zeros (each row's sort key is a pure function of its index), so a
    churn-dropped slot's rows are pushed past every live row and never
    enter a valid server minibatch.
    """
    return jnp.repeat(jnp.asarray(mask, jnp.float32), batch)


class FeatureStore(NamedTuple):
    """Pooled smashed data: features [T, ...], labels pytree of [T, ...].

    ``valid`` is an optional [T] row mask (1.0 = live row, 0.0 = a row
    contributed by a padded or churn-dropped cohort slot); ``None``
    means every row is live (the classic unpadded pool).
    """
    features: jax.Array
    labels: jax.Array
    valid: Optional[jax.Array] = None

    @classmethod
    def pool(cls, feature_batches, label_batches, mask=None) -> "FeatureStore":
        """[C, b, ...] per-client batches -> pooled [C*b, ...].
        Labels may be any pytree of [C, b, ...] arrays.  ``mask`` is an
        optional [C] cohort attendance mask; it is broadcast to a per-row
        validity mask over the pooled axis."""
        merge = lambda a: a.reshape((-1,) + a.shape[2:])
        valid = None
        if mask is not None:
            valid = valid_from_mask(mask, feature_batches.shape[1])
        return cls(merge(feature_batches), jax.tree.map(merge, label_batches),
                   valid)

    @property
    def size(self) -> int:
        return self.features.shape[0]


def resample_plan(key, total: int, epochs: int, batch: int) -> jax.Array:
    """Index plan [epochs, steps, batch]: a fresh permutation per server
    epoch (random-reshuffling — the paper's analog of centralized
    shuffling, §3.1).  Truncates the tail that doesn't fill a batch."""
    steps = total // batch
    keys = jax.random.split(key, epochs)
    perms = jax.vmap(lambda k: jax.random.permutation(k, total))(keys)
    return perms[:, : steps * batch].reshape(epochs, steps, batch)


def masked_resample_plan(key, valid, epochs: int,
                         batch: int) -> tuple[jax.Array, jax.Array]:
    """Padded-pool plan: [epochs, steps, batch] indices + [epochs, steps]
    step-validity mask.

    Each row r draws a sort key from ``uniform(fold_in(key_e, r))`` —
    a pure function of (epoch key, row id), independent of the pool's
    padded capacity — and padded rows are pushed to +inf, so the sorted
    order lists the live rows first, in a capacity-invariant random
    order.  A step is valid iff all ``batch`` of its rows are live,
    which reproduces the dense plan's drop-the-tail truncation for the
    live row count.
    """
    total = valid.shape[0]
    steps = total // batch
    rows = jnp.arange(total)
    n_valid = jnp.sum(valid > 0)

    def one_epoch(k):
        u = jax.vmap(lambda r: jax.random.uniform(jax.random.fold_in(k, r))
                     )(rows)
        return jnp.argsort(jnp.where(valid > 0, u, jnp.inf))

    perms = jax.vmap(one_epoch)(jax.random.split(key, epochs))
    plan = perms[:, : steps * batch].reshape(epochs, steps, batch)
    step_ok = ((jnp.arange(steps) + 1) * batch <= n_valid)
    return plan, jnp.broadcast_to(step_ok, (epochs, steps))


def gather_batch(store: FeatureStore, idx,
                 use_kernel: Optional[bool] = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Resample one server minibatch: ``out[i] = store[idx[i]]``.

    Backend-gated like ``fused_adam``: on TPU the row gather dispatches
    to the ``kernels.ops.feature_resample`` scalar-prefetch Pallas
    kernel (indices in SMEM, one source row-block streamed per output
    row-block — a pure HBM-bandwidth copy); elsewhere the XLA
    ``jnp.take`` lowering is kept (``use_kernel=True`` forces the kernel
    in interpret mode, which is what the CPU equivalence test
    exercises).  Both paths compute the identical gather.

    Caveat: GSPMD has no partitioning rule for a bare ``pallas_call``,
    so on a mesh with the pool sharded over 'data' XLA gathers the
    operand around the kernel — correct, but the gather is not
    shard-LOCAL.  :func:`shard_local_gather` is the ``shard_map`` wrapper
    with per-shard index translation that keeps it local (CycleConfig.
    shard_local_resample routes the server inner loop there); the jnp
    path partitions natively.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels import ops
        take = lambda a: ops.resample_rows(a, idx)
    else:
        take = lambda a: jnp.take(a, idx, axis=0)
    return take(store.features), jax.tree.map(take, store.labels)


def shard_slice_indices(idx, shard: int, rows_per_shard: int
                        ) -> tuple[jax.Array, jax.Array]:
    """Translate global gather indices into ONE shard's pool-slice frame.

    The index-translation contract of the shard-local resample: shard
    ``s`` owns the contiguous global rows ``[s * rows_per_shard, (s+1) *
    rows_per_shard)``; a global index lands in exactly one shard's
    slice, so across shards the ``ok`` masks partition the gather.
    Returns ``(local, ok)`` — ``local`` is clipped into ``[0,
    rows_per_shard)`` so masked-off rows still index safely (their
    gathered values are zeroed by the caller before the cross-shard
    fixup sum).
    """
    local = idx - shard * rows_per_shard
    ok = (local >= 0) & (local < rows_per_shard)
    return jnp.clip(local, 0, rows_per_shard - 1).astype(jnp.int32), ok


def shard_local_gather(store: FeatureStore, idx, mesh,
                       use_kernel: Optional[bool] = None,
                       replicate_out: bool = False
                       ) -> tuple[jax.Array, jax.Array]:
    """Shard-LOCAL resample: ``out[i] = store[idx[i]]`` without gathering
    the pooled operand around the kernel.

    GSPMD has no partitioning rule for a bare ``pallas_call``, so the
    kernel path of :func:`gather_batch` all-gathers D_S^f per minibatch
    on a sharded mesh.  This wrapper keeps the gather local: a
    ``shard_map`` over the pool's batch axes gives each shard only its
    contiguous row slice, per-shard index translation
    (:func:`shard_slice_indices`) selects the plan rows that land in the
    slice, and rows that don't are fixed up by a masked cross-shard sum
    — every output row has exactly ONE live contribution (the masks
    partition the gather), so the psum is value-exact and the result is
    bit-for-bit the GSPMD gather.  The plan indices are uniform over
    shards (``resample_plan``/``masked_resample_plan`` permutations are
    computed from the replicated round key), which is what makes the
    replicated-``idx`` in_spec correct.

    Communication: a reduce-scatter (or all-reduce when the minibatch
    doesn't divide the shards) of the [M, ...] minibatch instead of an
    all-gather of the [T, ...] pool — M << T in every CycleSL setting.
    Falls back to :func:`gather_batch` when the pool rows don't divide
    the batch axes (``pool_shard_info`` returns None).

    ``replicate_out=True`` forces the all-reduce (psum) form so the
    minibatch comes out replicated — the tensor-parallel server layout,
    where FSDP/TP-sharded weights want full rows on every device.  The
    psum sums one live contribution and n_shards - 1 exact zeros per
    row, so the values are still bit-for-bit the GSPMD gather.
    """
    from repro.sharding.specs import pool_shard_info
    info = pool_shard_info(mesh, store.size) if mesh is not None else None
    if info is None:
        return gather_batch(store, idx, use_kernel=use_kernel)
    axes, n_shards, rows_per_shard = info
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    lead = axes if len(axes) > 1 else axes[0]
    M = idx.shape[0]
    scatter = M % n_shards == 0 and not replicate_out

    def row_spec(a):
        return P(lead, *([None] * (a.ndim - 1)))

    def out_spec(a):
        return row_spec(a) if scatter else P(*([None] * a.ndim))

    def body(feats, labels, idx):
        shard = jnp.zeros((), jnp.int32)
        for a in axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        local, ok = shard_slice_indices(idx, shard, rows_per_shard)

        def take(a):
            if use_kernel:
                from repro.kernels import ops
                rows = ops.resample_rows(a, local)
            else:
                rows = jnp.take(a, local, axis=0)
            # mask off rows owned by other shards, then cross-shard
            # fixup: exactly one shard contributes each output row, so
            # summing the (n_shards - 1) zeros is value-exact
            rows = jnp.where(ok.reshape((-1,) + (1,) * (rows.ndim - 1)),
                             rows, jnp.zeros((), rows.dtype))
            if scatter:
                return jax.lax.psum_scatter(rows, lead,
                                            scatter_dimension=0, tiled=True)
            return jax.lax.psum(rows, lead)

        return take(feats), jax.tree.map(take, labels)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(row_spec(store.features),
                  jax.tree.map(row_spec, store.labels),
                  P(None)),
        out_specs=(out_spec(store.features),
                   jax.tree.map(out_spec, store.labels)),
        check_rep=False)
    return fn(store.features, store.labels, idx.astype(jnp.int32))


def shard_local_fused_loss(store: FeatureStore, idx, w, mesh,
                           use_kernel: Optional[bool] = None) -> jax.Array:
    """Mean fused gather+linear-head-loss over one server minibatch,
    computed INSIDE a ``shard_map`` over the pool's batch axes —
    differentiable in the head weights ``w`` only (D_S^f is data,
    paper Eq. 3).

    This is the shard-local composition of the two paths that could not
    previously coexist: the fused gather+loss kernel
    (``kernels.ops.fused_gather_loss_mean``) avoids materializing the
    gathered minibatch, but GSPMD has no partitioning rule for a bare
    ``pallas_call``, so on a sharded mesh it all-gathered D_S^f around
    the kernel — exactly the collective ``shard_local_gather`` exists to
    kill.  Here each shard runs the fused per-row loss over only the
    plan rows that land in ITS contiguous pool slice
    (:func:`shard_slice_indices`), masks the rest to exact zeros, and a
    scalar ``psum`` of the masked partial sums reassembles the
    minibatch-mean loss — one f32 scalar on the wire per step instead of
    the [T, ...] pool.  The backward pass is the analytic linear-head
    cross-entropy VJP computed the same way: per-shard
    ``dw = fᵀ dlogits`` partials over owned rows, psum'd.

    The masks partition the gather (each plan row has exactly one owner
    shard), so the loss equals the unsharded fused path up to summation
    order.  Falls back to ``fused_gather_loss_mean`` when the pool
    doesn't divide the batch axes.
    """
    from repro.kernels import ops
    from repro.sharding.specs import pool_shard_info
    info = pool_shard_info(mesh, store.size) if mesh is not None else None
    feats2 = store.features.reshape((store.size, -1))
    if info is None:
        return ops.fused_gather_loss_mean(feats2, store.labels, idx, w)
    axes, n_shards, rows_per_shard = info
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    lead = axes if len(axes) > 1 else axes[0]
    M = idx.shape[0]

    def shard_id():
        s = jnp.zeros((), jnp.int32)
        for a in axes:
            s = s * mesh.shape[a] + jax.lax.axis_index(a)
        return s

    def fwd_body(f_loc, l_loc, idx, w):
        local, ok = shard_slice_indices(idx, shard_id(), rows_per_shard)
        if use_kernel:
            losses = ops.gather_loss_microbatch(f_loc, l_loc, local, w)
        else:
            f = jnp.take(f_loc, local, axis=0).astype(jnp.float32)
            logits = f @ w.astype(jnp.float32)
            y = jnp.take(l_loc, local, axis=0).astype(jnp.int32)
            losses = (jax.nn.logsumexp(logits, axis=-1)
                      - jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0])
        losses = jnp.where(ok, losses, 0.0)
        return jax.lax.psum(jnp.sum(losses), lead) / M

    def bwd_body(f_loc, l_loc, idx, w, g):
        local, ok = shard_slice_indices(idx, shard_id(), rows_per_shard)
        f = jnp.take(f_loc, local, axis=0).astype(jnp.float32)
        logits = f @ w.astype(jnp.float32)
        y = jnp.take(l_loc, local, axis=0)
        p = jax.nn.softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(y, w.shape[1], dtype=jnp.float32)
        # rows owned by other shards contribute exact zeros to dw
        dlog = jnp.where(ok[:, None], (p - onehot) * (g / M), 0.0)
        return jax.lax.psum(f.T @ dlog, lead).astype(w.dtype)

    row = lambda a: P(lead, *([None] * (a.ndim - 1)))
    fwd_sm = shard_map(fwd_body, mesh=mesh,
                       in_specs=(row(feats2), P(lead), P(None), P(None, None)),
                       out_specs=P(), check_rep=False)
    bwd_sm = shard_map(bwd_body, mesh=mesh,
                       in_specs=(row(feats2), P(lead), P(None), P(None, None),
                                 P()),
                       out_specs=P(None, None), check_rep=False)

    @jax.custom_vjp
    def fused(feats2, labels, idx, w):
        return fwd_sm(feats2, labels, idx, w)

    def fused_fwd(feats2, labels, idx, w):
        return fused(feats2, labels, idx, w), (feats2, labels, idx, w)

    def fused_bwd(res, g):
        import numpy as np
        feats2, labels, idx, w = res
        dw = bwd_sm(feats2, labels, idx, w, g)
        zero = lambda x: (np.zeros(x.shape, jax.dtypes.float0)
                          if jnp.issubdtype(x.dtype, jnp.integer)
                          else jnp.zeros_like(x))
        return zero(feats2), zero(labels), zero(idx), dw

    fused.defvjp(fused_fwd, fused_bwd)
    return fused(feats2, store.labels, idx.astype(jnp.int32), w)


def pool_store(feats, ys, mask=None, mesh=None) -> FeatureStore:
    """Build the pooled, placement-pinned D_S^f handoff for one cohort.

    The single construction point both execution schedules share: the
    monolithic round pools inside ``ServerUpdate``, while the pipelined
    extract dispatch pools here and hands the finished store to the
    in-flight tail (``PipelineStage.store``) — identical ops either way
    (stop_gradient + reshape + the broadcast validity mask), which is
    what keeps the pipelined round bit-for-bit the sequential one.
    """
    return constrain_store(
        FeatureStore.pool(jax.lax.stop_gradient(feats), ys, mask=mask), mesh)


def constrain_store(store: FeatureStore, mesh) -> FeatureStore:
    """Pin the pooled arrays' row dim to the mesh batch axes so D_S^f
    stays sharded over 'data' through the server inner loop (the paper's
    pooled feature dataset is the one [C*b, ...] tensor per round whose
    placement GSPMD would otherwise replicate)."""
    from repro.sharding.specs import constrain_cohort
    if mesh is None:
        return store
    return store._replace(
        features=constrain_cohort(store.features, mesh),
        labels=jax.tree.map(lambda l: constrain_cohort(l, mesh),
                            store.labels),
        valid=(None if store.valid is None
               else constrain_cohort(store.valid, mesh)))


class RingEntry(NamedTuple):
    """One in-flight cohort awaiting its tail: the round it will be
    consumed at, the round whose pre-tail state its extract read
    (``src_round``; consumption round - src_round = realized θ_S lag),
    the extracted :class:`~repro.api.phases.PipelineStage`, and the
    host-side cohort inputs (clean + fault-injected) the tail and any
    recovery re-extract need."""
    round: int
    src_round: int
    stage: object
    inputs: object
    inj_inputs: object


class StaleFeatureRing:
    """Bounded buffer of in-flight extracted stages — the structure that
    delivers a round-k extract into the round-k+L pool.

    The Engine pushes ``extract(k+L)`` (dispatched against round k's
    pre-tail state) and pops entry ``k`` just before ``tail(k)``, so at
    most ``depth`` stages are ever in flight and the realized snapshot
    lag of any consumed entry is bounded by ``depth`` *by construction*
    (``push`` asserts the bound; ``pop`` asserts FIFO order and records
    the realized lag).  ``rewind`` is the recovery hook: after a
    retried/rolled-back round every buffered stage was extracted from a
    discarded state, so each is re-extracted from the accepted one.
    """

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"ring depth must be >= 1, got {depth}")
        self.depth = depth
        self._entries: list[RingEntry] = []
        self.realized_lags: list[int] = []

    def __len__(self) -> int:
        return len(self._entries)

    def push(self, round: int, src_round: int, stage, inputs, inj_inputs):
        assert len(self._entries) < self.depth, \
            f"ring overflow: {len(self._entries)} stages in flight " \
            f"(depth {self.depth})"
        assert round - src_round <= self.depth, \
            f"stage for round {round} extracted at {src_round} would " \
            f"exceed the lag bound {self.depth}"
        if self._entries:
            assert round == self._entries[-1].round + 1, "non-contiguous push"
        self._entries.append(
            RingEntry(round, src_round, stage, inputs, inj_inputs))

    def pop(self, round: int) -> RingEntry:
        assert self._entries and self._entries[0].round == round, \
            f"expected round {round} at ring head, have " \
            f"{[e.round for e in self._entries]}"
        entry = self._entries.pop(0)
        self.realized_lags.append(entry.round - entry.src_round)
        return entry

    def rewind(self, extract_fn, src_round: int):
        """Re-extract every buffered stage from the accepted state
        (recovery rewound the run past the states they were read from).
        ``extract_fn(inj_inputs)`` must read the accepted state."""
        self._entries = [
            e._replace(stage=extract_fn(e.inj_inputs), src_round=src_round)
            for e in self._entries]

    @property
    def max_realized_lag(self) -> int:
        return max(self.realized_lags, default=0)
