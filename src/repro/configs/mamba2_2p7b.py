"""mamba2-2.7b [arXiv:2405.21060] — SSD (state-space duality).

64L d_model=2560, attention-free, ssm_state=128, vocab=50280.
d_inner = 2*2560 = 5120, head_dim 64 -> 80 SSD heads.
"""
from repro.configs.base import ArchConfig, AttnConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,          # attention-free; SSD heads live in SSMConfig
    n_kv_heads=1,
    d_ff=0,
    vocab=50_280,
    attn=AttnConfig(),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    cut_layers=4,
    tie_embeddings=True,
    dtype="bfloat16",
    source="arXiv:2405.21060",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=256, vocab=512, cut_layers=1, dtype="float32",
        ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=32,
                      n_groups=1, chunk=32))
