"""whisper-base [arXiv:2212.04356] — encoder-decoder, conv frontend STUB.

6L encoder + 6L decoder, d_model=512 8H d_ff=2048 vocab=51865.  The
mel-spectrogram + conv feature extractor is stubbed: ``input_specs``
provides precomputed frame embeddings [B, 1500, 512].

Split-learning cut: encoder = client, decoder = server (DESIGN.md §5).
long_500k is SKIPPED for this arch (full-attention decoder with a
448-position practical horizon); decode_32k runs the windowed variant.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51_865,
    head_dim=64,
    attn=AttnConfig(rope=False),
    enc_layers=6,
    enc_d_model=512,
    cut_layers=0,       # cut at the enc/dec boundary, not inside a stack
    tie_embeddings=True,
    dtype="bfloat16",
    source="arXiv:2212.04356",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, enc_layers=2, d_model=128, enc_d_model=128,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
        dtype="float32")
