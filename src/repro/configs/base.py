"""Architecture / input-shape config dataclasses.

Every assigned architecture gets one ``configs/<id>.py`` exporting
``CONFIG: ArchConfig`` with the exact published hyper-parameters (source
cited in the module docstring) plus ``smoke()`` returning a reduced
variant (<=2 layers, d_model<=512, <=4 experts) for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class AttnConfig:
    """Attention hyper-parameters (GQA + RoPE + gemma2 extras)."""

    rope: bool = True               # whisper uses learned abs. positions
    rope_theta: float = 10_000.0
    logit_softcap: Optional[float] = None        # gemma2: 50.0 on attn logits
    final_softcap: Optional[float] = None        # gemma2: 30.0 on lm logits
    window: Optional[int] = None                 # sliding-window size (local attn)
    # 'global' | 'local' | 'local_global' (gemma2 alternating, even=local)
    pattern: str = "global"
    qk_norm: bool = False


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0            # per-expert hidden dim
    capacity_factor: float = 1.25
    router_z_weight: float = 1e-3   # router z-loss
    aux_weight: float = 1e-2        # load-balance aux loss
    n_shared_experts: int = 0       # moonshot/deepseek-style always-on experts
    # how to shard the expert dim on the 'model' mesh axis:
    #   'expert' — expert-parallel (n_experts % model_axis == 0)
    #   'ffn'    — tensor-parallel inside each expert (grok: 8e on 16-way)
    shard_mode: str = "expert"
    group_size: int = 4096          # dispatch group (tokens) to bound buffers


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block hyper-parameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256                # SSD chunk length
    # hybrid (zamba2): positions (block indices) where the shared attention
    # block is applied; empty for pure SSM.
    shared_attn_positions: tuple[int, ...] = ()


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # split-learning cut: number of transformer blocks on the client side
    # (embedding is always client-side; final norm + head always server-side)
    cut_layers: int = 2
    dtype: str = "float32"
    # enc-dec (whisper): encoder depth/width (decoder uses the main fields)
    enc_layers: int = 0
    enc_d_model: int = 0
    # vlm: number of prefix patch-embedding positions fed by the stub
    n_patch_tokens: int = 0
    # serving: sliding-window override used for the long_500k carve-out
    long_context_window: int = 16_384
    tie_embeddings: bool = False
    sandwich_norm: bool = False     # gemma2 pre+post block norms
    norm_eps: float = 1e-5
    source: str = ""                # citation

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding/head vocab padded to a multiple of 128 so the vocab
        dim shards on the 16-way model axis (Megatron-style padding;
        mamba2's 50280 and whisper's 51865 are otherwise unshardable and
        replicate full-cohort logits on every device — see §Perf)."""
        return ((self.vocab + 127) // 128) * 128

    @property
    def jnp_dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs in the roofline)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        qkv = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + self.n_heads * hd * d
        per_layer = qkv + 2 * d  # attn + norms
        if self.moe is not None:
            per_layer += self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            per_layer += d * self.moe.n_experts  # router
            if f:  # shared dense ffn alongside moe (moonshot-style) not modeled
                pass
        elif self.ssm is not None and self.family in ("ssm", "hybrid"):
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            per_layer = (d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + nh)
                         + di * self.ssm.d_conv + di * d + 2 * d)
        if f and self.moe is None and self.family not in ("ssm", "hybrid"):
            per_layer += 3 * d * f  # swiglu
        total = self.n_layers * per_layer + v * d + d
        if self.family == "hybrid":
            # one SHARED attention+ffn block (zamba2), parameters counted
            # once; compute-wise it runs len(shared_attn_positions) times,
            # which n_active_params reflects.
            total += qkv + 3 * d * f
        if not self.tie_embeddings:
            total += v * d
        if self.enc_layers:
            ed = self.enc_d_model or d
            total += self.enc_layers * (4 * ed * ed + 2 * ed * self.d_ff + 2 * ed)
        return int(total)

    def n_active_params(self) -> int:
        """Params touched per token (MoE: top_k experts; zamba2: the
        shared block once per application site)."""
        full = self.n_params()
        d = self.d_model
        if self.family == "hybrid" and self.ssm is not None:
            n_apps = len(self.ssm.shared_attn_positions)
            hd = self.hd
            qkv = (d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads
                   + self.n_heads * hd * d)
            shared = qkv + 3 * d * self.d_ff
            return int(full + (n_apps - 1) * shared)
        if self.moe is None:
            return full
        moe_all = self.n_layers * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        moe_act = self.n_layers * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return int(full - moe_all + moe_act)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
