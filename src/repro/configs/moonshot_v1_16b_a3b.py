"""moonshot-v1-16b-a3b — Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) vocab=163840; MoE 64 experts top-6 with
d_ff_expert=1408 and 2 shared experts (DeepSeek-V3-style).  Assignment
tag: [dense] (dense attention + MoE FFN).
"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="dense",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163_840,
    head_dim=128,
    attn=AttnConfig(rope_theta=50_000.0),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2, shard_mode="expert"),
    cut_layers=2,
    dtype="bfloat16",
    source="hf:moonshotai/Moonlight-16B-A3B",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=128, vocab=512, cut_layers=1, dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                      n_shared_experts=1, shard_mode="expert"))
