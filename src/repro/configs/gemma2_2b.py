"""gemma2-2b [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000.
Alternating local(4096-window)/global attention, logit softcap 50 /
final softcap 30, sandwich (pre+post) norms, tied embeddings.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab=256_000,
    head_dim=256,
    attn=AttnConfig(rope_theta=10_000.0, logit_softcap=50.0,
                    final_softcap=30.0, window=4096, pattern="local_global"),
    cut_layers=2,       # one local/global pair (period=2)
    tie_embeddings=True,
    sandwich_norm=True,
    dtype="bfloat16",
    source="arXiv:2408.00118",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512, cut_layers=2, dtype="float32",
        attn=AttnConfig(logit_softcap=50.0, final_softcap=30.0,
                        window=16, pattern="local_global"))
