"""grok-1-314b [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072; MoE 8 experts
top-2.  8 experts on a 16-way model axis -> per-expert tensor parallelism
(shard_mode='ffn'), see DESIGN.md §5.
"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32_768,
    vocab=131_072,
    head_dim=128,
    attn=AttnConfig(rope_theta=10_000.0),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32_768,
                  shard_mode="ffn"),
    cut_layers=1,
    dtype="bfloat16",
    source="hf:xai-org/grok-1",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512, cut_layers=1, dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, shard_mode="ffn"))
