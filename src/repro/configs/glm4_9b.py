"""glm4-9b [hf:THUDM/glm-4-9b].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552, RoPE.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13_696,
    vocab=151_552,
    head_dim=128,
    attn=AttnConfig(rope_theta=10_000.0),
    cut_layers=2,
    dtype="bfloat16",
    source="hf:THUDM/glm-4-9b",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512, cut_layers=1, dtype="float32")
