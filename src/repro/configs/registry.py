"""Architecture registry: ``--arch <id>`` -> ArchConfig (full or smoke)."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

_ARCH_MODULES = {
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "glm4-9b": "repro.configs.glm4_9b",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3p8b",
    "whisper-base": "repro.configs.whisper_base",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {list_archs()}")
    return importlib.import_module(_ARCH_MODULES[arch])


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def smoke_config(arch: str) -> ArchConfig:
    return _module(arch).smoke()
