"""olmoe-1b-7b [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16) vocab=50304; MoE 64 experts top-8,
d_ff_expert=1024, qk-norm.
"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50_304,
    head_dim=128,
    attn=AttnConfig(rope_theta=10_000.0, qk_norm=True),
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024,
                  shard_mode="expert"),
    cut_layers=2,
    dtype="bfloat16",
    source="arXiv:2409.02060",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=128, vocab=512, cut_layers=1, dtype="float32",
        attn=AttnConfig(qk_norm=True),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                      shard_mode="expert"))
