"""phi3-mini-3.8b [arXiv:2404.14219].

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064, RoPE + SwiGLU.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_064,
    head_dim=96,
    attn=AttnConfig(rope_theta=10_000.0),
    cut_layers=2,
    dtype="bfloat16",
    source="arXiv:2404.14219",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab=512, cut_layers=1, dtype="float32")
