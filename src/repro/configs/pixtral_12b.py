"""pixtral-12b [hf:mistralai/Pixtral-12B-2409].

Language backbone = Mistral-Nemo-12B: 40L d_model=5120 32H (GQA kv=8,
head_dim=128) d_ff=14336 vocab=131072.  The Pixtral-ViT vision encoder +
projector is a STUB: ``input_specs`` feeds precomputed patch embeddings
for the first ``n_patch_tokens`` positions (assignment carve-out).
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=131_072,
    head_dim=128,
    attn=AttnConfig(rope_theta=1_000_000.0),
    n_patch_tokens=1024,
    cut_layers=2,
    dtype="bfloat16",
    source="hf:mistralai/Pixtral-12B-2409",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512, n_patch_tokens=8, cut_layers=1, dtype="float32")
