"""zamba2-1.2b [arXiv:2411.15242] — Mamba2 backbone + shared attention.

38 mamba2 blocks, d_model=2048, ssm_state=64; ONE shared transformer
block (32H attention + d_ff=8192 SwiGLU) applied at two interleave
points (we use block indices 12 and 25), vocab=32000.
"""
from repro.configs.base import ArchConfig, AttnConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_000,
    head_dim=64,
    attn=AttnConfig(rope_theta=10_000.0),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256, shared_attn_positions=(12, 25)),
    cut_layers=4,
    tie_embeddings=True,
    dtype="bfloat16",
    source="arXiv:2411.15242",
)


def smoke() -> ArchConfig:
    return CONFIG.with_(
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab=512, cut_layers=1, dtype="float32",
        ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=32,
                      n_groups=1, chunk=32, shared_attn_positions=(1,)))
