from repro.configs.base import (
    ArchConfig,
    AttnConfig,
    MoEConfig,
    SSMConfig,
    InputShape,
    INPUT_SHAPES,
)
from repro.configs.registry import get_config, list_archs, smoke_config
