"""Deterministic fault-injection streams (styled after scenario/profiles).

Every draw is a pure fold-in of ``(seed, salt, round[, attempt])``
through ``np.random.default_rng`` — never a stateful stream — so every
recovery path the Engine takes is replayable under test: two
independently-built streams agree on which rounds are poisoned, which
dispatches raise, and which checkpoint writes are torn, regardless of
query order or how many recovery attempts a round consumed.

Three fault kinds, mirroring what a real fleet throws at the server:

* ``nan``   — poisoned client delivery: ``nan_slots(rnd, attempt)``
  names the cohort slots whose feature batch arrives as NaN that round.
  By default a fault clears after the first attempt (a transient link),
  so retry/rollback recover; ``persist`` extends it across recovery
  attempts — then only quarantining the slot saves the round.
* ``error`` — a dispatch raises (preempted host, OOM, link loss):
  ``check_dispatch(rnd, attempt)`` raises :class:`FaultInjectedError`
  before the round/extract/tail dispatch runs.  Attempt-keyed, so a
  retry lands on a healthy draw.
* ``ckpt``  — a torn checkpoint write: ``ckpt_corrupt(step)`` says
  whether to truncate the just-written step's array file, exercising the
  restore-past-corrupt fallback in :mod:`repro.checkpoint.io`.
"""
from __future__ import annotations

import argparse
import os
from dataclasses import asdict, dataclass, fields
from typing import Optional

import numpy as np

# fixed fold-in salts (never derived from hash(): PYTHONHASHSEED-proof)
_NAN_SALT = 0xFA01
_ERROR_SALT = 0xFA02
_CKPT_SALT = 0xFA03


class FaultInjectedError(RuntimeError):
    """A deterministically-injected dispatch failure.

    ``site`` names where the fault fired ('round', 'extract', 'tail');
    the Engine's recovery controller treats it as the 'error' fault kind
    (policy ``on_error``).  Escapes the run unhandled when no recovery
    is configured — an unguarded Engine dies on it, by design.
    """

    def __init__(self, site: str, rnd: int, attempt: int):
        super().__init__(f"injected {site} fault at round {rnd} "
                         f"(attempt {attempt})")
        self.site = site
        self.rnd = rnd
        self.attempt = attempt


@dataclass(frozen=True)
class FaultConfig:
    """Serializable fault-injection knobs (rides ``ResilienceConfig``).

    All rates are per-round probabilities in [0, 1); a zero-rate config
    builds no stream at all (:func:`build_fault_stream` returns None)
    and the Engine's fault hooks are never consulted.
    """
    nan_rate: float = 0.0          # P[a round's delivery is poisoned]
    nan_slots: int = 1             # cohort slots poisoned when it fires
    error_rate: float = 0.0        # P[a dispatch raises] per attempt
    ckpt_rate: float = 0.0         # P[a checkpoint write is torn]
    persist: int = 0               # recovery attempts a NaN fault outlives
                                   # (0 = clears after the first attempt)
    seed: Optional[int] = None     # stream seed (None = experiment seed)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultConfig":
        d = dict(d)
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown FaultConfig fields: {sorted(unknown)}")
        return cls(**d)

    @classmethod
    def from_spec(cls, spec: str, seed: Optional[int] = None) -> "FaultConfig":
        """Parse the compact ``--faults`` flag syntax:
        ``"nan=0.2,error=0.1,ckpt=0.5,slots=2,persist=3"`` (any subset)."""
        kw: dict = {"seed": seed}
        if spec:
            for part in spec.split(","):
                k, _, val = part.partition("=")
                key = {"nan": "nan_rate", "error": "error_rate",
                       "ckpt": "ckpt_rate", "slots": "nan_slots",
                       "persist": "persist"}.get(k.strip())
                if key is None:
                    raise KeyError(f"unknown fault spec key {k!r} in {spec!r}"
                                   " (expected nan/error/ckpt/slots/persist)")
                kw[key] = (int(val) if key in ("nan_slots", "persist")
                           else float(val))
        return cls(**kw).validate()

    def validate(self) -> "FaultConfig":
        for name in ("nan_rate", "error_rate", "ckpt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"faults.{name}={v} must be in [0, 1)")
        if self.nan_slots < 1:
            raise ValueError(f"faults.nan_slots={self.nan_slots} must be >= 1")
        if self.persist < 0:
            raise ValueError(f"faults.persist={self.persist} must be >= 0")
        return self

    @property
    def any(self) -> bool:
        return (self.nan_rate > 0 or self.error_rate > 0
                or self.ckpt_rate > 0)


class FaultStream:
    """Deterministic per-round fault generator.

    One instance per run; every query is a pure function of
    ``(seed, salt, round[, attempt])`` so recovery replays are exact.
    """

    def __init__(self, cfg: FaultConfig, seed: int):
        self.cfg = cfg.validate()
        self.seed = int(cfg.seed if cfg.seed is not None else seed)

    # deterministic fold-in: a fresh Generator per (seed, salt, ...)
    def _rng(self, *salt: int) -> np.random.Generator:
        return np.random.default_rng([int(s) & 0xFFFFFFFF for s in
                                      (self.seed, *salt)])

    # ------------------------------------------------------------- kinds
    def nan_slots_for(self, rnd: int, attempt: int,
                      live: int) -> np.ndarray:
        """Cohort slot indices whose features are poisoned this attempt
        ([0] .. [live) ints, possibly empty).  The round-level draw (does
        the fault fire, and on which slots) depends only on ``rnd``;
        ``attempt`` only gates persistence — a retry past
        ``cfg.persist`` attempts lands on a clean delivery.
        """
        cfg = self.cfg
        if cfg.nan_rate <= 0 or live <= 0 or attempt > cfg.persist:
            return np.empty(0, np.int64)
        rng = self._rng(_NAN_SALT, rnd)
        if rng.random() >= cfg.nan_rate:
            return np.empty(0, np.int64)
        k = min(cfg.nan_slots, live)
        return np.sort(rng.choice(live, size=k, replace=False))

    def check_dispatch(self, rnd: int, attempt: int,
                       site: str = "round") -> None:
        """Raise :class:`FaultInjectedError` when the (rnd, attempt)
        dispatch draw fires.  Attempt-keyed: a retry redraws."""
        if self.cfg.error_rate <= 0:
            return
        u = self._rng(_ERROR_SALT, rnd, attempt).random()
        if u < self.cfg.error_rate:
            raise FaultInjectedError(site, rnd, attempt)

    def ckpt_corrupt(self, step: int) -> bool:
        """Whether the write of checkpoint ``step`` should be torn."""
        if self.cfg.ckpt_rate <= 0:
            return False
        return bool(self._rng(_CKPT_SALT, step).random()
                    < self.cfg.ckpt_rate)

    # --------------------------------------------------------- mutations
    @staticmethod
    def corrupt_checkpoint(ckpt_dir: str, step: int,
                           keep_bytes: int = 64) -> str:
        """Tear a written checkpoint: truncate its array payload to
        ``keep_bytes`` (a partial write frozen mid-flight).  The manifest
        survives, so only the content checksum can tell — exactly the
        failure mode the restore fallback must skip."""
        path = os.path.join(ckpt_dir, f"step_{step}", "arrays.npz")
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(min(keep_bytes, size))
        return path


def build_fault_stream(cfg: Optional[FaultConfig], seed: int
                       ) -> Optional[FaultStream]:
    """Resolve a FaultConfig into a stream; ``None`` when no fault kind
    has a positive rate (the Engine then never consults the hooks)."""
    if cfg is None or not cfg.any:
        return None
    return FaultStream(cfg, seed)


def add_fault_arguments(ap: argparse.ArgumentParser
                        ) -> argparse.ArgumentParser:
    ap.add_argument("--faults", default="",
                    help="deterministic fault injection spec, e.g. "
                         "'nan=0.2,error=0.1,ckpt=0.3,slots=2,persist=0' "
                         "(empty = no injection)")
    ap.add_argument("--faults-seed", type=int, default=None,
                    help="fault stream seed (default: run seed)")
    return ap
