"""In-trace health-guard math: NaN/Inf and loss-spike detection.

Everything here is pure ``jnp`` on values the round already computes —
the committed TrainState, the round's server loss, the cohort's smashed
data and feature gradients — so the :class:`~repro.api.phases.HealthGuard`
phase folds the checks into the SAME jitted round (one trace, no extra
dispatches).  The Engine reads back one small ``health`` vector per
round (the single host sync the guard costs) and the per-slot blame
array only when the verdict is bad.

Layout of the packed ``metrics['health']`` vector (float32 [4]):

    [0] nonfinite — 1.0 when the loss, the committed params/opt state,
        or any live slot's features/feature-gradients contain NaN/Inf
    [1] spike     — 1.0 when the loss exceeds ``spike_factor`` x the
        EMA of accepted losses (armed only once the EMA is warm; the
        Engine additionally host-gates on ``spike_warmup`` rounds)
    [2] new_ema   — the EMA updated with this round's loss (fed back as
        next round's ``ema`` input IF the round is accepted)
    [3] slot_bad_any — 1.0 when any live slot is to blame (quarantine
        has a target)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# metrics['health'] slot names, in packing order
HEALTH_NONFINITE, HEALTH_SPIKE, HEALTH_EMA, HEALTH_SLOT_ANY = range(4)


def tree_all_finite(tree) -> jax.Array:
    """Scalar bool: every inexact leaf of ``tree`` is NaN/Inf-free.

    Integer leaves (step counters, index plans) are skipped — they are
    finite by construction and ``isfinite`` rejects them anyway.
    """
    flags = [jnp.all(jnp.isfinite(leaf))
             for leaf in jax.tree.leaves(tree)
             if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)]
    if not flags:
        return jnp.asarray(True)
    out = flags[0]
    for f in flags[1:]:
        out = out & f
    return out


def slot_nonfinite(arrs, n_slots: int, mask=None) -> jax.Array:
    """[C] float32 blame vector: 1.0 where a LIVE cohort slot delivered
    NaN/Inf in any of ``arrs`` (each a [C, ...] stack or None).

    Padded/churn-dropped slots (mask 0) are never blamed — their zeroed
    payloads are clean by construction and quarantining them is a no-op.
    """
    bad = jnp.zeros((n_slots,), jnp.float32)
    for a in arrs:
        if a is None:
            continue
        flat = a.reshape((a.shape[0], -1)).astype(jnp.float32)
        bad = jnp.maximum(bad,
                          jnp.any(~jnp.isfinite(flat), axis=-1)
                          .astype(jnp.float32))
    if mask is not None:
        bad = bad * (jnp.asarray(mask, jnp.float32) > 0)
    return bad


def masked_tree_all_finite(tree, mask=None) -> jax.Array:
    """:func:`tree_all_finite`, but leaves whose leading axis matches the
    [C] ``mask`` are checked on LIVE slots only.

    Per-slot intermediates (feature gradients, per-slot losses) carry a
    quarantined slot's NaN harmlessly — every consumer where-masks it
    out (pooled means, ``select_entities`` commits) — so a health check
    that read those entries would flag a round the recovery already
    fixed and spin until the retry budget burns out.
    """
    if mask is None:
        return tree_all_finite(tree)
    live = jnp.asarray(mask) > 0
    n = live.shape[0]
    flags = []
    for leaf in jax.tree.leaves(tree):
        leaf = jnp.asarray(leaf)
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            continue
        ok = jnp.isfinite(leaf)
        if leaf.ndim >= 1 and leaf.shape[0] == n:
            ok = ok | ~live.reshape((n,) + (1,) * (leaf.ndim - 1))
        flags.append(jnp.all(ok))
    if not flags:
        return jnp.asarray(True)
    out = flags[0]
    for f in flags[1:]:
        out = out & f
    return out


def ema_update(ema, loss, alpha: float) -> jax.Array:
    """One EMA step over ACCEPTED losses.  ``ema == 0`` is the unarmed
    sentinel (seeded by the first finite loss); a non-finite loss leaves
    the EMA untouched so a faulted round cannot poison the detector."""
    ema = jnp.asarray(ema, jnp.float32)
    loss = jnp.asarray(loss, jnp.float32)
    finite = jnp.isfinite(loss)
    seeded = jnp.where(ema != 0.0, (1.0 - alpha) * ema + alpha * loss, loss)
    return jnp.where(finite, seeded, ema)


def health_vector(state, loss, feats, fgrads, mask, ema,
                  alpha: float, spike_factor: float
                  ) -> tuple[jax.Array, jax.Array]:
    """The packed [4] health vector + the [C] slot-blame array.

    ``feats``/``fgrads`` may be None (fused sequential programs carry no
    per-slot intermediates) — slot blame then stays all-zero and the
    Engine's quarantine policy escalates to retry.

    Slot BLAME reads the smashed data only: features are produced
    per-client BEFORE anything is shared, so a NaN there names the
    offending client unambiguously.  Feature gradients are NOT blamed —
    one poisoned slot's rows pollute the pooled server update and every
    slot's gradient goes NaN downstream of it (guilt by contagion, not a
    culprit).  ``fgrads`` still feeds the round-level nonfinite check —
    on LIVE slots only, so a freshly-quarantined slot's inert NaN
    gradient cannot re-flag the round it was just excised from.
    """
    loss = jnp.asarray(loss, jnp.float32)
    n_slots = feats.shape[0] if feats is not None else 1
    slot_bad = slot_nonfinite([feats], n_slots, mask=mask)
    fgrads_ok = (masked_tree_all_finite(fgrads, mask) if fgrads is not None
                 else jnp.asarray(True))
    finite = (tree_all_finite(state) & jnp.isfinite(loss)
              & fgrads_ok & (jnp.max(slot_bad) == 0))
    ema = jnp.asarray(ema if ema is not None else 0.0, jnp.float32)
    spike = (ema != 0.0) & jnp.isfinite(loss) & (loss > spike_factor * ema)
    health = jnp.stack([
        (~finite).astype(jnp.float32),
        spike.astype(jnp.float32),
        ema_update(ema, loss, alpha),
        (jnp.max(slot_bad) > 0).astype(jnp.float32),
    ])
    return health, slot_bad
