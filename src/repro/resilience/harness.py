"""Crash/resume worker: the subprocess half of the SIGKILL-mid-round proof.

Runs a small image-task Engine with ``eval_every=1`` (one checkpoint per
round) and prints its result as one JSON line, so a driver (the test
suite, or a human) can:

1. launch it, wait for ``step_K`` to appear, and SIGKILL it mid-round;
2. relaunch with ``--resume`` and compare the resumed history tail
   bit-for-bit against an uninterrupted golden run.

``--sleep-per-round`` widens the kill window deterministically (a plain
``time.sleep`` inside an ``on_round`` callback — the device work is done
when it fires, so the kill always lands between a committed round and
the next checkpoint, never inside the atomic write's rename).

Usage::

    python -m repro.resilience.harness --ckpt-dir /tmp/ck --rounds 6
    python -m repro.resilience.harness --ckpt-dir /tmp/ck --rounds 6 \
        --resume --out result.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.resilience.config import ResilienceConfig
from repro.resilience.faults import FaultConfig


class _SleepEachRound:
    def __init__(self, seconds: float):
        self.seconds = seconds

    def on_round(self, engine, rnd, state, metrics):
        if self.seconds > 0:
            time.sleep(self.seconds)


def build_engine(args):
    # imported here so ``--help`` stays fast and the module can be
    # imported without pulling in jax
    from repro.api.config import ExperimentConfig
    from repro.api.engine import Engine

    cfg = ExperimentConfig(
        algo=args.algo, task="image", rounds=args.rounds,
        n_clients=args.clients, attendance=args.attendance,
        min_cohort=2, batch=args.batch, eval_every=1,
        width=8, cut=1, seed=args.seed,
        pipeline_depth=args.pipeline_depth,
        pipeline_staleness=args.pipeline_staleness,
        ckpt_dir=args.ckpt_dir, resume=args.resume,
        resilience=ResilienceConfig(
            guard=args.guard,
            faults=FaultConfig.from_spec(args.faults)),
    )
    return Engine(cfg, callbacks=(_SleepEachRound(args.sleep_per_round),),
                  log=lambda *a: print(*a, file=sys.stderr))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--algo", default="cyclesfl")
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--attendance", type=float, default=0.25)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore from the newest valid checkpoint")
    ap.add_argument("--guard", action="store_true",
                    help="arm the in-trace health guards")
    ap.add_argument("--faults", default="",
                    help="fault-injection spec (see repro.resilience.faults)")
    ap.add_argument("--pipeline-depth", type=int, default=0,
                    help="run the pipelined (extract, tail) schedule "
                         "with an L-deep staleness ring")
    ap.add_argument("--pipeline-staleness", default="sync",
                    choices=("sync", "async"))
    ap.add_argument("--sleep-per-round", type=float, default=0.0,
                    help="host sleep after each round (widens the "
                         "SIGKILL window for the crash test)")
    ap.add_argument("--out", default=None,
                    help="write the result JSON here (default: stdout)")
    args = ap.parse_args(argv)

    result = build_engine(args).run()
    payload = json.dumps({
        "history": result["history"],
        "resumed_from_round": result.get("resumed_from_round", 0),
        "resilience": result.get("resilience"),
    })
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload)
    else:
        print(payload)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
