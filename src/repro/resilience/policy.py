"""Host-side recovery controller: the bookkeeping half of the guards.

The compiled round computes the health verdict (see
:mod:`repro.resilience.guards`); this module owns everything that lives
OUTSIDE the trace — the per-fault action table, the retry/backoff
budget, the bounded in-memory ring of last-good TrainState snapshots,
the quarantine ledger feeding the cohort sampler, and the per-round
telemetry the run result reports.

The controller never touches device state itself: the Engine asks it
what to do (``action_for``), hands it accepted states to remember
(``note_accept``), and pulls restore targets from it (``rollback``).
Snapshots are plain references — TrainStates are immutable pytrees and
the Engine disables buffer donation while recovery is active, so holding
them costs no copy.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Optional, Sequence

import numpy as np

from repro.resilience.config import ACTIONS, ResilienceConfig

# fault kinds a verdict can name (order = severity for telemetry only)
FAULT_KINDS = ("nonfinite", "spike", "error")


class ResilienceExhaustedError(RuntimeError):
    """Every configured recovery action failed for one round."""

    def __init__(self, rnd: int, attempts: int, kinds: Sequence[str]):
        super().__init__(
            f"round {rnd}: recovery exhausted after {attempts} attempts "
            f"(faults seen: {sorted(set(kinds))}); raise "
            "resilience.max_retries, widen the policy, or fix the fault")
        self.rnd = rnd
        self.attempts = attempts


def quarantine_mask(mask: np.ndarray, slot_bad: np.ndarray) -> np.ndarray:
    """Zero the blamed slots out of a [C] attendance mask.

    Pure and shape-preserving — exactly the transform the Engine applies
    before a quarantine re-run, and the function the Hypothesis property
    drives: a blamed slot's mask entry reads 0, so its pooled feature
    rows are invalid before ServerUpdate resamples and its commit is a
    structural no-op (the PR 6 churn semantics, reused verbatim).
    """
    mask = np.asarray(mask, np.float32)
    bad = np.asarray(slot_bad, np.float32)
    return (mask * (bad <= 0)).astype(np.float32)


class RecoveryController:
    """Per-run recovery state machine (one instance per Engine.run)."""

    def __init__(self, cfg: ResilienceConfig, n_clients: int,
                 min_live: int = 1, log=print, sleep=time.sleep):
        self.cfg = cfg.validate()
        self.n_clients = int(n_clients)
        self.min_live = int(min_live)
        self.log = log
        self.sleep = sleep
        self.ring: deque = deque(maxlen=cfg.ring_size)  # (rnd, state, ema)
        self.quarantined: set[int] = set()
        # (round, ids) per quarantine event — the replayable form of the
        # ledger: resume rebuilds `quarantined` as of any past round from
        # this, so a restored run's cohort draws match the original's
        self.quarantine_history: list[tuple[int, tuple[int, ...]]] = []
        self.rows: list[dict] = []
        self.totals = {"retries": 0, "rollbacks": 0,
                       "quarantine_events": 0, "faulted_rounds": 0,
                       "faults": {k: 0 for k in FAULT_KINDS}}
        self._accepted = 0            # accepted rounds (spike warmup gate)

    # ------------------------------------------------------------ policy
    def action_for(self, kind: str, attempt: int) -> str:
        """The action for fault ``kind`` on recovery attempt ``attempt``.

        The configured action leads; if it proved inapplicable on an
        earlier attempt of the same round the Engine walks the
        escalation tail via :meth:`escalate`.
        """
        base = {"nonfinite": self.cfg.on_nonfinite,
                "spike": self.cfg.on_spike,
                "error": self.cfg.on_error}[kind]
        return base

    @staticmethod
    def escalate(action: str) -> Optional[str]:
        """Next action when ``action`` cannot apply (no blamable slot,
        empty snapshot ring): quarantine -> retry -> rollback -> None."""
        ladder = [a for a in ACTIONS if a != "ignore"]
        i = ladder.index(action) if action in ladder else -1
        return ladder[i + 1] if 0 <= i < len(ladder) - 1 else None

    def spike_armed(self) -> bool:
        return self._accepted >= self.cfg.spike_warmup

    def backoff(self, attempt: int) -> None:
        if self.cfg.backoff_base_s > 0:
            self.sleep(self.cfg.backoff_base_s * (2.0 ** (attempt - 1)))

    # --------------------------------------------------------- snapshots
    def note_accept(self, rnd: int, state, ema) -> None:
        """Record an accepted round; snapshot on the configured cadence.
        Called once per accepted round, faulted or not.  ``ema`` is kept
        as-is (a device scalar) — no host sync here."""
        self._accepted += 1
        if self.cfg.guard and self._accepted % self.cfg.snapshot_every == 0:
            self.ring.append((rnd, state, ema))

    def rollback(self) -> Optional[tuple[int, object, float]]:
        """Pop the newest last-good snapshot (None when the ring is
        empty).  Consumed on use so repeated faults walk further back."""
        if not self.ring:
            return None
        self.totals["rollbacks"] += 1
        return self.ring.pop()

    # -------------------------------------------------------- quarantine
    def quarantine(self, cohort: np.ndarray, mask: np.ndarray,
                   slot_bad: np.ndarray,
                   rnd: Optional[int] = None) -> Optional[np.ndarray]:
        """Blame -> new mask + ledger update; None when inapplicable.

        Inapplicable when no LIVE slot with a real client id is blamed,
        or when zeroing the blamed slots would leave fewer than one live
        slot (the server inner loop would see an empty pool) — the
        caller then escalates.
        """
        cohort = np.asarray(cohort)
        mask = np.asarray(mask, np.float32)
        bad = (np.asarray(slot_bad) > 0) & (mask > 0) \
            & (cohort < self.n_clients)
        if not bad.any():
            return None
        new_mask = quarantine_mask(mask, bad)
        if new_mask.sum() < 1:
            return None
        ids = sorted(int(c) for c in cohort[bad])
        self.quarantined.update(ids)
        self.quarantine_history.append(
            (-1 if rnd is None else int(rnd), tuple(ids)))
        self.totals["quarantine_events"] += 1
        self.log(f"[resilience] quarantined clients {ids} "
                 f"({len(self.quarantined)} total)")
        return new_mask

    def sampling_weights(self, base: Optional[np.ndarray]
                         ) -> Optional[np.ndarray]:
        """Fold the quarantine ledger into the cohort-sampling weights.

        ``None`` in, no quarantine -> ``None`` out (the sampler keeps
        the exact scenario-free draw path).  With quarantined clients
        their weight is zeroed — unless that would starve the sampler
        below ``min_live`` candidates, in which case the ledger is
        ignored for sampling (better a suspect client than no cohort).
        """
        if not self.quarantined:
            return base
        w = (np.ones(self.n_clients, np.float64) if base is None
             else np.asarray(base, np.float64).copy())
        w[list(self.quarantined)] = 0.0
        if (w > 0).sum() < max(self.min_live, 1):
            return base
        return w

    # ------------------------------------------------------- persistence
    def export_state(self) -> dict:
        """JSON-serializable controller state for checkpoint metadata.

        Covers the parts a resumed run must not forget: the quarantine
        ledger (set + per-round event history, so sampling replay can
        reconstruct the set as of any round), the accepted-round count
        (the spike-warmup gate), and the recovery totals.  The snapshot
        ring and telemetry rows are deliberately NOT persisted — ring
        entries are live device pytrees (the checkpoint itself is the
        last-good state after a resume) and rows are per-run telemetry.
        """
        return {
            "quarantined": sorted(self.quarantined),
            "quarantine_history": [[r, list(ids)]
                                   for r, ids in self.quarantine_history],
            "accepted": self._accepted,
            "totals": {**{k: v for k, v in self.totals.items()
                          if k != "faults"},
                       "faults": dict(self.totals["faults"])},
        }

    def restore_state(self, d: dict) -> None:
        """Inverse of :meth:`export_state` (tolerates older metadata
        missing keys: absent fields keep their fresh-run defaults)."""
        self.quarantined = set(int(c) for c in d.get("quarantined", ()))
        self.quarantine_history = [
            (int(r), tuple(int(c) for c in ids))
            for r, ids in d.get("quarantine_history", ())]
        self._accepted = int(d.get("accepted", 0))
        totals = d.get("totals", {})
        for k in self.totals:
            if k == "faults":
                for fk in self.totals["faults"]:
                    self.totals["faults"][fk] = int(
                        totals.get("faults", {}).get(fk, 0))
            else:
                self.totals[k] = int(totals.get(k, self.totals[k]))

    def quarantined_as_of(self, rnd: int) -> set[int]:
        """The quarantine set as of the START of round ``rnd`` (events
        from earlier rounds only) — the set the original run's sampler
        saw when drawing round ``rnd``'s cohort."""
        return {int(c) for r, ids in self.quarantine_history if r < rnd
                for c in ids}

    # --------------------------------------------------------- telemetry
    def record_round(self, rnd: int, attempts: int, kinds: list[str],
                     actions: list[str], quarantined_now: int) -> None:
        """One telemetry row per round that needed ANY recovery work."""
        if attempts == 0:
            return
        self.totals["faulted_rounds"] += 1
        for k in kinds:
            self.totals["faults"][k] += 1
        self.totals["retries"] += sum(a == "retry" for a in actions)
        self.rows.append({"round": rnd, "attempts": attempts,
                          "faults": list(kinds), "actions": list(actions),
                          "quarantined_slots": quarantined_now})

    def summary(self) -> dict:
        return {
            "retries": self.totals["retries"],
            "rollbacks": self.totals["rollbacks"],
            "quarantine_events": self.totals["quarantine_events"],
            "quarantined_clients": sorted(self.quarantined),
            "faulted_rounds": self.totals["faulted_rounds"],
            "faults": dict(self.totals["faults"]),
            "snapshots_held": len(self.ring),
            "per_round": list(self.rows),
        }
