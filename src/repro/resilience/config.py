"""Serializable resilience knobs (rides ``ExperimentConfig.resilience``).

The null config (``ResilienceConfig()``) is the contract anchor: no
guard phase is appended, no recovery controller is built, no snapshot is
taken — the Engine is bit-for-bit the guard-free one, with the
one-trace-per-(algo, config, mesh) budget untouched.
"""
from __future__ import annotations

import argparse
from dataclasses import asdict, dataclass, field, fields
from typing import Optional

from repro.resilience.faults import FaultConfig

# recovery actions a policy can name, in escalation order: an action
# that cannot apply (no blamable slot, empty snapshot ring) falls
# through to the next one rather than wedging the round
ACTIONS = ("ignore", "quarantine", "retry", "rollback")


@dataclass(frozen=True)
class ResilienceConfig:
    """Health guards + per-fault recovery policies + fault injection.

    ``guard=True`` appends the :class:`~repro.api.phases.HealthGuard`
    phase inside the compiled round (NaN/Inf over loss, feature grads,
    and the committed TrainState, plus the EMA loss-spike detector) and
    arms the Engine's recovery controller.  The three ``on_*`` knobs
    pick the action per fault kind:

    * ``quarantine`` — zero the blamed cohort slots in the attendance
      mask (the PR 6 churn machinery), ban those clients from future
      cohorts, and re-run the round from the pre-round state.
    * ``retry``      — re-run the round from the pre-round state after
      exponential backoff (transient faults clear on redraw).
    * ``rollback``   — restore the newest snapshot from the in-memory
      last-good ring and re-run the current round from it.
    * ``ignore``     — record telemetry, accept the round as-is.
    """
    guard: bool = False
    on_nonfinite: str = "quarantine"  # NaN/Inf in loss/grads/params
    on_spike: str = "ignore"          # EMA loss-spike divergence
    on_error: str = "retry"           # dispatch raised (host exception)
    max_retries: int = 3              # recovery attempts per round
    backoff_base_s: float = 0.0       # sleep base * 2^(attempt-1) between
                                      # attempts (0 = no backoff, tests)
    ring_size: int = 2                # last-good TrainState snapshots
    snapshot_every: int = 1           # accepted rounds between snapshots
    ema_alpha: float = 0.1            # loss-EMA smoothing
    spike_factor: float = 4.0         # loss > factor * EMA = spike
    spike_warmup: int = 5             # accepted rounds before spikes arm
    faults: FaultConfig = field(default_factory=FaultConfig)

    # -------------------------------------------------------- round-trips
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ResilienceConfig":
        d = dict(d)
        faults = d.pop("faults", {})
        if not isinstance(faults, FaultConfig):
            faults = FaultConfig.from_dict(faults)
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(
                f"unknown ResilienceConfig fields: {sorted(unknown)}")
        return cls(faults=faults, **d)

    def validate(self) -> "ResilienceConfig":
        for name in ("on_nonfinite", "on_spike", "on_error"):
            action = getattr(self, name)
            if action not in ACTIONS:
                raise ValueError(f"resilience.{name}={action!r}: expected "
                                 f"one of {ACTIONS}")
        if self.max_retries < 0:
            raise ValueError(f"resilience.max_retries={self.max_retries} "
                             "must be >= 0")
        if self.ring_size < 1:
            raise ValueError(f"resilience.ring_size={self.ring_size} "
                             "must be >= 1")
        if self.snapshot_every < 1:
            raise ValueError(f"resilience.snapshot_every="
                             f"{self.snapshot_every} must be >= 1")
        if self.backoff_base_s < 0:
            raise ValueError(f"resilience.backoff_base_s="
                             f"{self.backoff_base_s} must be >= 0")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError(f"resilience.ema_alpha={self.ema_alpha} must "
                             "be in (0, 1]")
        if self.spike_factor <= 1.0:
            raise ValueError(f"resilience.spike_factor={self.spike_factor} "
                             "must be > 1")
        self.faults.validate()
        return self

    @property
    def active(self) -> bool:
        """True when the Engine must build a recovery controller (guards
        armed, or faults injected — an injected dispatch error needs the
        controller even with guards off)."""
        return self.guard or self.faults.any

    @property
    def quarantines(self) -> bool:
        return self.guard and "quarantine" in (self.on_nonfinite,
                                               self.on_spike, self.on_error)

    # -------------------------------------------------------------- flags
    @staticmethod
    def add_arguments(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
        from repro.resilience.faults import add_fault_arguments
        ap.add_argument("--guard", action="store_true",
                        help="arm in-trace health guards (NaN/Inf + loss "
                             "spike) and the recovery controller")
        ap.add_argument("--on-nonfinite", default="quarantine",
                        choices=ACTIONS,
                        help="recovery action for NaN/Inf faults")
        ap.add_argument("--on-spike", default="ignore", choices=ACTIONS,
                        help="recovery action for loss-spike divergence")
        ap.add_argument("--on-error", default="retry", choices=ACTIONS,
                        help="recovery action for dispatch exceptions")
        ap.add_argument("--max-retries", type=int, default=3,
                        help="recovery attempts per round before the run "
                             "gives up")
        ap.add_argument("--backoff-base-s", type=float, default=0.0,
                        help="exponential-backoff base between recovery "
                             "attempts (seconds)")
        ap.add_argument("--snapshot-ring", type=int, default=2,
                        help="in-memory last-good TrainState snapshots "
                             "kept for rollback")
        add_fault_arguments(ap)
        return ap

    @classmethod
    def from_flags(cls, args: argparse.Namespace) -> "ResilienceConfig":
        return cls(guard=args.guard,
                   on_nonfinite=args.on_nonfinite,
                   on_spike=args.on_spike,
                   on_error=args.on_error,
                   max_retries=args.max_retries,
                   backoff_base_s=args.backoff_base_s,
                   ring_size=args.snapshot_ring,
                   faults=FaultConfig.from_spec(args.faults,
                                                seed=args.faults_seed)
                   ).validate()
