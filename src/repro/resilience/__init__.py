"""Fault-tolerant training runtime: guards, recovery, fault injection.

Four pieces, split by where they run:

* :mod:`~repro.resilience.guards`  — pure ``jnp`` health checks folded
  into the compiled round (NaN/Inf + EMA loss-spike).
* :mod:`~repro.resilience.policy`  — the host-side
  :class:`RecoveryController` (quarantine ledger, retry budget,
  last-good snapshot ring, telemetry).
* :mod:`~repro.resilience.faults`  — deterministic fault-injection
  streams (pure (seed, salt, round) fold-ins, scenario-profile style).
* :mod:`~repro.resilience.config`  — the serializable
  :class:`ResilienceConfig` riding ``ExperimentConfig.resilience``.

The null config is free: no guard phase, no controller, no snapshots —
bit-for-bit the guard-free Engine with the trace budget untouched.
"""
from repro.resilience.config import ACTIONS, ResilienceConfig
from repro.resilience.faults import (FaultConfig, FaultInjectedError,
                                     FaultStream, add_fault_arguments,
                                     build_fault_stream)
from repro.resilience.guards import (HEALTH_EMA, HEALTH_NONFINITE,
                                     HEALTH_SLOT_ANY, HEALTH_SPIKE,
                                     ema_update, health_vector,
                                     masked_tree_all_finite,
                                     slot_nonfinite, tree_all_finite)
from repro.resilience.policy import (FAULT_KINDS, RecoveryController,
                                     ResilienceExhaustedError,
                                     quarantine_mask)

__all__ = [
    "ACTIONS", "ResilienceConfig",
    "FaultConfig", "FaultInjectedError", "FaultStream",
    "add_fault_arguments", "build_fault_stream",
    "HEALTH_EMA", "HEALTH_NONFINITE", "HEALTH_SLOT_ANY", "HEALTH_SPIKE",
    "ema_update", "health_vector", "masked_tree_all_finite",
    "slot_nonfinite", "tree_all_finite",
    "FAULT_KINDS", "RecoveryController", "ResilienceExhaustedError",
    "quarantine_mask",
]
