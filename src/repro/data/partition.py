"""Non-iid partitioning strategies (paper §4.1, Appendix I).

* ``dirichlet_partition`` — FL-bench-style Dirichlet(alpha) label skew:
  smaller alpha => stronger heterogeneity (paper CIFAR-100 setup).
* ``power_law_sizes`` — LEAF-style heavy-tailed samples-per-client
  histogram (paper Figure 2).
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        rng: np.random.Generator,
                        min_per_client: int = 2) -> list[np.ndarray]:
    """Split sample indices across clients with Dirichlet(alpha) label skew.

    Returns a list of index arrays, one per client.  alpha=inf -> iid.
    """
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        if np.isinf(alpha):
            props = np.full(n_clients, 1.0 / n_clients)
        else:
            props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].extend(part.tolist())
    out = []
    for i in range(n_clients):
        arr = np.asarray(client_idx[i], dtype=np.int64)
        rng.shuffle(arr)
        out.append(arr)
    # re-seat clients that got starved (keeps every client usable)
    pool = np.concatenate(out) if out else np.arange(len(labels))
    for i in range(n_clients):
        if len(out[i]) < min_per_client:
            take = rng.choice(pool, size=min_per_client, replace=False)
            out[i] = np.asarray(take, dtype=np.int64)
    return out


def power_law_sizes(n_clients: int, total: int, rng: np.random.Generator,
                    exponent: float = 1.5, min_size: int = 8) -> np.ndarray:
    """LEAF-like heavy-tailed client dataset sizes summing ~total."""
    raw = rng.pareto(exponent, size=n_clients) + 1.0
    sizes = np.maximum(min_size, (raw / raw.sum() * total).astype(int))
    return sizes
