"""Federated dataset container + cohort (partial-attendance) sampling.

Implements the paper's experimental protocol: sample-wise 90/10
train/test split per client (§4.1) and a 5% attendance rate per round.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ClientData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    def sample_indices(self, rng: np.random.Generator, batch: int):
        """The one RNG draw behind a batch — exposed so a resumed run can
        fast-forward the sampling stream without materializing arrays."""
        return rng.choice(len(self.x_train), size=batch,
                          replace=len(self.x_train) < batch)

    def sample_batch(self, rng: np.random.Generator, batch: int):
        idx = self.sample_indices(rng, batch)
        return self.x_train[idx], self.y_train[idx]


@dataclass
class FederatedDataset:
    clients: list[ClientData] = field(default_factory=list)

    @classmethod
    def from_arrays(cls, x, y, client_indices, test_frac: float = 0.1,
                    min_train: int = 2, seed: int = 0) -> "FederatedDataset":
        """Sample-wise split per client (paper §4.1).  Clients that cannot
        fill a batch are kept but may resample with replacement."""
        rng = np.random.default_rng(seed)
        clients = []
        for idx in client_indices:
            idx = np.asarray(idx)
            rng.shuffle(idx)
            n_test = max(1, int(len(idx) * test_frac))
            if len(idx) - n_test < min_train:
                n_test = max(0, len(idx) - min_train)
            te, tr = idx[:n_test], idx[n_test:]
            clients.append(ClientData(x[tr], y[tr], x[te], y[te]))
        return cls(clients)

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    def test_arrays(self):
        xs = np.concatenate([c.x_test for c in self.clients if len(c.x_test)])
        ys = np.concatenate([c.y_test for c in self.clients if len(c.y_test)])
        return xs, ys


def sample_cohort(n_clients: int, attendance: float,
                  rng: np.random.Generator, min_cohort: int = 1,
                  variable: bool = False,
                  max_cohort: int | None = None,
                  weights: np.ndarray | None = None) -> np.ndarray:
    """Partial participation: sample distinct attending clients.

    ``variable=False`` (the paper's protocol) fixes the cohort size at
    ``round(attendance * N)``.  ``variable=True`` models realistic
    availability: each client attends i.i.d. with probability
    ``attendance``, so the per-round size is Binomial(N, attendance) —
    clipped to ``[min_cohort, max_cohort]`` so padded execution has a
    static capacity to pad to.

    ``weights`` (optional, length N, need not be normalized) biases the
    draw toward more-available clients — scenario streams with
    time-varying availability (diurnal churn) feed their per-round
    profile weights here.  ``None`` keeps the uniform draw path:
    ``rng.choice`` uses a DIFFERENT algorithm when ``p=`` is given, so
    uniform scenarios must pass ``None`` (not a flat array) to stay
    bit-for-bit with the scenario-free sampler.
    """
    if variable:
        k = int(rng.binomial(n_clients, attendance))
    else:
        k = int(round(attendance * n_clients))
    k = max(min_cohort, k)
    if max_cohort is not None:
        k = min(k, max_cohort)
    if weights is not None:
        p = np.asarray(weights, np.float64)
        p = p / p.sum()
        return rng.choice(n_clients, size=min(k, n_clients), replace=False,
                          p=p)
    return rng.choice(n_clients, size=min(k, n_clients), replace=False)
