from repro.data.partition import dirichlet_partition, power_law_sizes
from repro.data.synthetic import (SyntheticImageTask, SyntheticCharLMTask,
                                  SyntheticRegressionTask)
from repro.data.federated import FederatedDataset, sample_cohort
