"""Synthetic tasks with the paper's failure modes (offline stand-ins for
LEAF / CIFAR-100 / OpenEDS2020 — see DESIGN.md §7).

The image task draws class prototypes and *client-conditioned* styles:
each sample is ``prototype[label] + client_style[client] + noise``, so a
client's feature distribution is shifted (feature heterogeneity) on top
of Dirichlet label skew — exactly the client-drift regime CycleSL
targets.  Learnable on CPU in a few hundred SL rounds.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.partition import dirichlet_partition


@dataclass
class SyntheticImageTask:
    """K-class image-like classification, client-conditioned Gaussians."""

    n_classes: int = 10
    img: int = 16
    channels: int = 3
    n_clients: int = 100
    samples_per_client: int = 64
    alpha: float = 0.5              # Dirichlet label skew (inf = iid)
    style_scale: float = 0.6        # client feature-shift strength
    noise: float = 0.35
    seed: int = 0

    def _smooth_patterns(self, rng, n: int, scale: float) -> np.ndarray:
        """Low-frequency spatial patterns (coarse grid, bilinear-upsampled)
        — conv-learnable class signal, unlike white-noise prototypes."""
        coarse = rng.normal(size=(n, 4, 4, self.channels)).astype(np.float32)
        # bilinear upsample 4x4 -> img x img
        xs = np.linspace(0, 3, self.img)
        x0 = np.clip(xs.astype(int), 0, 2)
        fx = (xs - x0)[None, :, None, None]
        up = (coarse[:, x0] * (1 - fx) + coarse[:, x0 + 1] * fx)
        up = np.swapaxes(up, 1, 2)
        up = (up[:, x0] * (1 - fx) + up[:, x0 + 1] * fx)
        up = np.swapaxes(up, 1, 2)
        flat = up.reshape(n, -1)
        flat /= np.linalg.norm(flat, axis=1, keepdims=True) / scale
        return flat

    def build(self):
        rng = np.random.default_rng(self.seed)
        d = self.img * self.img * self.channels
        protos = self._smooth_patterns(rng, self.n_classes,
                                       scale=np.sqrt(d) * 0.5)
        styles = self._smooth_patterns(rng, self.n_clients,
                                       scale=np.sqrt(d) * self.style_scale)

        total = self.n_clients * self.samples_per_client
        labels = rng.integers(0, self.n_classes, size=total).astype(np.int64)
        parts = dirichlet_partition(labels, self.n_clients, self.alpha, rng)

        xs, ys, owner = [], [], []
        for ci, idx in enumerate(parts):
            lab = labels[idx]
            x = (protos[lab]
                 + styles[ci]
                 + self.noise * rng.normal(size=(len(idx), d)).astype(np.float32))
            xs.append(x.astype(np.float32))
            ys.append(lab)
            owner.append(np.full(len(idx), ci, np.int64))
        x = np.concatenate(xs).reshape(-1, self.img, self.img, self.channels)
        y = np.concatenate(ys)
        o = np.concatenate(owner)
        client_indices = []
        offs = 0
        for idx in parts:
            client_indices.append(np.arange(offs, offs + len(idx)))
            offs += len(idx)
        return x, y, o, client_indices


@dataclass
class SyntheticCharLMTask:
    """Char-LM stand-in for Shakespeare: client-specific Markov chains."""

    vocab: int = 80
    seq_len: int = 20
    n_clients: int = 50
    samples_per_client: int = 128
    heterogeneity: float = 0.7      # mix weight of the client's own chain
    seed: int = 0

    def build(self):
        rng = np.random.default_rng(self.seed)
        base = rng.dirichlet(np.ones(self.vocab) * 0.3, size=self.vocab)
        xs, ys, client_indices = [], [], []
        offs = 0
        for ci in range(self.n_clients):
            own = rng.dirichlet(np.ones(self.vocab) * 0.3, size=self.vocab)
            trans = (self.heterogeneity * own
                     + (1 - self.heterogeneity) * base)
            seqs = np.empty((self.samples_per_client, self.seq_len + 1), np.int64)
            state = rng.integers(0, self.vocab, self.samples_per_client)
            seqs[:, 0] = state
            for t in range(1, self.seq_len + 1):
                cdf = np.cumsum(trans[state], axis=1)
                u = rng.random((self.samples_per_client, 1))
                state = (u > cdf).sum(axis=1).clip(0, self.vocab - 1)
                seqs[:, t] = state
            xs.append(seqs[:, :-1])
            ys.append(seqs[:, -1])      # next-char prediction target
            client_indices.append(np.arange(offs, offs + self.samples_per_client))
            offs += self.samples_per_client
        return (np.concatenate(xs), np.concatenate(ys),
                np.repeat(np.arange(self.n_clients), self.samples_per_client),
                client_indices)


@dataclass
class SyntheticRegressionTask:
    """Gaze-estimation stand-in (OpenEDS2020): per-client bias regression."""

    d_in: int = 64
    d_out: int = 2                 # gaze direction (yaw, pitch)
    n_clients: int = 40
    samples_per_client: int = 96
    client_bias: float = 0.4
    noise: float = 0.1
    seed: int = 0

    def build(self):
        rng = np.random.default_rng(self.seed)
        w = rng.normal(size=(self.d_in, self.d_out)).astype(np.float32) * 0.3
        xs, ys, client_indices = [], [], []
        offs = 0
        for ci in range(self.n_clients):
            bias = rng.normal(size=(1, self.d_out)).astype(np.float32) * self.client_bias
            x = rng.normal(size=(self.samples_per_client, self.d_in)).astype(np.float32)
            y = np.tanh(x @ w) + bias + self.noise * rng.normal(
                size=(self.samples_per_client, self.d_out)).astype(np.float32)
            xs.append(x)
            ys.append(y.astype(np.float32))
            client_indices.append(np.arange(offs, offs + self.samples_per_client))
            offs += self.samples_per_client
        return (np.concatenate(xs), np.concatenate(ys),
                np.repeat(np.arange(self.n_clients), self.samples_per_client),
                client_indices)
