from repro.sharding.specs import (param_specs, named_shardings, batch_spec,
                                  shard_if_divisible, RULES)
