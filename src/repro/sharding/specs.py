"""Path-regex sharding rules (t5x-style) for every repro model.

The production mesh is (data=16, model=16) per pod; multi-pod adds a
leading 'pod' axis used for batch/cohort parallelism only.  Weights are
sharded 2-D: FSDP over 'data' + tensor-parallel over 'model' — this is
what lets grok-1-314b fit 16 GiB/chip (DESIGN.md §3).

Rules give a spec *template for the trailing dims* of a leaf; leading
dims (stacked layer dim, stacked client dim in the CycleSL cohort) are
handled by role:

  role='server'/'full' — stacked-layer leading dim replicated.
  role='client'        — an extra leading cohort dim sharded over
                         ('pod','data'); the 'data' FSDP component inside
                         the rule is dropped (an axis may appear once).
"""
from __future__ import annotations

import re
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.tree import map_with_path

# (regex over '/'-joined leaf path, trailing-dims spec template)
# templates use axis names; None = replicated dim.
RULES: list[tuple[str, tuple]] = [
    # embeddings / heads
    (r"embed/table$", ("model", "data")),
    (r"lm_head/w$", ("data", "model")),
    (r"(encoder|decoder)/pos$", (None, "data")),
    # attention projections
    (r"attn/wq$", ("data", "model")),
    (r"attn/wk$", ("data", "model")),
    (r"attn/wv$", ("data", "model")),
    (r"attn/wo$", ("model", "data")),
    # dense ffn
    (r"ffn/w_gate$", ("data", "model")),
    (r"ffn/w_up$", ("data", "model")),
    (r"ffn/w_down$", ("model", "data")),
    (r"ffn/w_in$", ("data", "model")),
    (r"ffn/b_in$", ("model",)),
    (r"ffn/w_out$", ("model", "data")),
    # moe (expert-parallel by default; grok overrides via shard_mode)
    (r"moe/router$", ("data", None)),
    (r"moe/w_gate$", ("model", "data", None)),
    (r"moe/w_up$", ("model", "data", None)),
    (r"moe/w_down$", ("model", None, "data")),
    # CNN/MLP dense layers (the CycleSL server stage at the deep cuts):
    # FSDP over the input dim + TP over the output dim.  Without this
    # the server inner loop all-reduces the FULL dense gradient and
    # runs full-size adam on every device each scan step — the dominant
    # ServerUpdate cost in the 1->8 device weak-scaling loss (§Weak
    # scaling, ARCHITECTURE.md).  shard_if_divisible drops either axis
    # when the dim doesn't divide.
    (r"lin/w$", ("data", "model")),
    # mamba2
    (r"mamba/w_in$", ("data", "model")),
    (r"mamba/conv_w$", (None, "model")),
    (r"mamba/w_out$", ("model", "data")),
    (r"mamba/(a_log|dt_bias|D)$", ("model",)),
    (r"mamba/gate_norm/scale$", ("model",)),
    # everything else (norms, biases, conv_b): replicated
    (r".*", ()),
]

MOE_FFN_MODE_RULES: list[tuple[str, tuple]] = [
    (r"moe/w_gate$", (None, "data", "model")),
    (r"moe/w_up$", (None, "data", "model")),
    (r"moe/w_down$", (None, "model", "data")),
]


def shard_if_divisible(dim: int, axis: Optional[str], mesh: Mesh):
    """Drop a sharding axis when the dim doesn't divide the axis size."""
    if axis is None:
        return None
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        if a not in mesh.shape:
            return None
        size *= mesh.shape[a]
    return axis if dim % size == 0 else None


def _spec_for(path: str, shape: Sequence[int], mesh: Mesh,
              rules: list[tuple[str, tuple]], role: str) -> P:
    template: tuple = ()
    for pat, tpl in rules:
        if re.search(pat, path):
            template = tpl
            break
    nd = len(shape)
    nt = len(template)
    lead = [None] * (nd - nt)
    axes = list(lead) + list(template[:nd])
    if role == "client":
        # drop 'data' (used by the cohort dim), then shard the leading
        # cohort dim over ('pod','data') / 'data'.
        axes = [None if a == "data" else a for a in axes]
        cohort_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        if axes:
            axes[0] = cohort_axes if len(cohort_axes) > 1 else (
                cohort_axes[0] if cohort_axes else None)
    # divisibility guard, per dim
    out = []
    for d, a in zip(shape, axes):
        out.append(shard_if_divisible(d, a, mesh) if a is not None else None)
    return P(*out)


def param_specs(params, mesh: Mesh, role: str = "full",
                moe_shard_mode: str = "expert"):
    """Pytree of PartitionSpec matching ``params``.

    role: 'full'/'server' — plain model params;
          'client'        — params stacked with a leading cohort dim.
    """
    rules = RULES
    if moe_shard_mode == "ffn":
        rules = MOE_FFN_MODE_RULES + RULES
    return map_with_path(
        lambda path, leaf: _spec_for(path, leaf.shape, mesh, rules, role),
        params)


def named_shardings(params, mesh: Mesh, role: str = "full",
                    moe_shard_mode: str = "expert"):
    specs = param_specs(params, mesh, role, moe_shard_mode)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------------
# Activation-batch constraints.  GSPMD propagates FSDP *weight*
# shardings into activations (the 'data' axis lands on d_model and the
# batch dim silently replicates — §Perf iteration 5).  Model code calls
# ``constrain_batch`` after the embedding and after every block group;
# the launcher registers the mesh here before tracing.
_ACTIVATION_MESH: Mesh | None = None


def set_activation_mesh(mesh: Mesh | None):
    global _ACTIVATION_MESH
    _ACTIVATION_MESH = mesh


def get_activation_mesh():
    return _ACTIVATION_MESH


def constrain_batch(x, batch_dims: int = 1):
    """Constrain the leading dim(s) of an activation to the batch axes.

    batch_dims=2 handles cohort-stacked [C, b, ...] activations: C takes
    the batch axes, b stays unsharded.  No-op when no mesh registered
    (CPU tests) or the dim doesn't divide.
    """
    mesh = _ACTIVATION_MESH
    if mesh is None or not hasattr(x, "ndim") or x.ndim < batch_dims + 1:
        return x
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if not axes or x.shape[0] % size != 0:
        axes = ("data",) if "data" in mesh.shape else ()
        size = mesh.shape.get("data", 1) if axes else 1
        if not axes or x.shape[0] % size != 0:
            return x
    lead = axes if len(axes) > 1 else axes[0]
    spec = P(lead, *([None] * (x.ndim - 1)))
    try:
        from jax.sharding import NamedSharding
        from jax.lax import with_sharding_constraint
        return with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:  # outside jit/mesh context
        return x


def pool_shard_info(mesh: Optional[Mesh], total: int
                    ) -> Optional[tuple[tuple[str, ...], int, int]]:
    """Per-shard pool-slice geometry for the shard-local resample.

    Mirrors :func:`batch_spec`'s axis choice for a pooled ``[T, ...]``
    feature array (the leading rows over ``('pod', 'data')``, falling
    back to ``'data'`` alone when T doesn't divide the combined size) and
    returns ``(axes, n_shards, rows_per_shard)`` — shard ``s`` owns the
    contiguous global row slice ``[s * rows_per_shard, (s+1) *
    rows_per_shard)``.  ``None`` means the pool cannot be evenly
    sliced over any batch axis (the caller must keep the GSPMD gather).
    """
    if mesh is None:
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if not axes or total % size != 0:
        if "data" in mesh.shape and total % mesh.shape["data"] == 0:
            axes, size = ("data",), mesh.shape["data"]
        else:
            return None
    return axes, size, total // size


def pool_slice_spec(mesh: Mesh, total: int, ndim: int) -> Optional[P]:
    """PartitionSpec of one pooled ``[T, ...]`` array under the per-shard
    slice geometry of :func:`pool_shard_info` (leading rows over the
    batch axes, trailing dims replicated); ``None`` when the pool has no
    even slicing."""
    info = pool_shard_info(mesh, total)
    if info is None:
        return None
    axes, _, _ = info
    lead = axes if len(axes) > 1 else axes[0]
    return P(lead, *([None] * (ndim - 1)))


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """Shard the leading batch dim over ('pod','data') if divisible."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if not axes or batch % size != 0:
        # try 'data' alone
        if "data" in mesh.shape and batch % mesh.shape["data"] == 0:
            return P("data", *([None] * extra_dims))
        return P(*([None] * (1 + extra_dims)))
    lead = axes if len(axes) > 1 else axes[0]
    return P(lead, *([None] * extra_dims))


# ------------------------------------------------------------------
# Mesh-native round execution: the constraint points every RoundProgram
# phase threads when the Engine runs on a mesh.  All of these are value-
# neutral (with_sharding_constraint only pins layout), which is what
# makes the 1-device-mesh path bit-for-bit equal to the unsharded one.
def _wsc(x, mesh: Mesh, spec: P):
    from jax.lax import with_sharding_constraint
    try:
        return with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:
        if isinstance(x, jax.core.Tracer):
            raise               # inside a trace a bad spec is a real bug
        return x                # eager/abstract use: layout hint only


def constrain_cohort(x, mesh: Optional[Mesh]):
    """Constrain a [C, ...] cohort-stacked (or [T, ...] pooled-row) array:
    leading dim over the batch axes, trailing dims replicated.  No-op when
    the leading dim doesn't divide the batch axes (batch_spec guard)."""
    if mesh is None or not hasattr(x, "ndim") or x.ndim < 1:
        return x
    return _wsc(x, mesh, batch_spec(mesh, x.shape[0], x.ndim - 1))


def constrain_cohort_tree(tree, mesh: Optional[Mesh]):
    """constrain_cohort over every leaf of a cohort-stacked pytree (the
    [C, ...] EntityState stacks the phases carry)."""
    if mesh is None:
        return tree
    return jax.tree.map(lambda l: constrain_cohort(l, mesh), tree)


def constrain_entity_params(params, mesh: Optional[Mesh], role: str = "server"):
    """Pin a params pytree to its path-rule weight placement (FSDP/TP).

    The pipelined Engine threads this through the extract dispatch's
    θ_S^t snapshot: the snapshot stays on the model/weight axes while
    every other stage tensor sits on the batch axes — disjoint axis
    placement, so XLA can run cohort k+1's extraction concurrently with
    cohort k's server inner loop instead of serializing them on a shared
    axis.  Value-neutral (layout only); no-op off-mesh.
    """
    if mesh is None or params is None:
        return params
    specs = param_specs(params, mesh, role)
    return jax.tree.map(lambda l, s: _wsc(l, mesh, s), params, specs)


def params_are_sharded(params, mesh: Optional[Mesh],
                       role: str = "server") -> bool:
    """True when any leaf of ``params`` gets a non-replicated spec under
    the path rules — i.e. the entity runs FSDP/TP on this mesh.  Purely
    static (shapes + rules); safe to call at trace time."""
    if mesh is None or params is None:
        return False
    for spec in jax.tree.leaves(param_specs(params, mesh, role),
                                is_leaf=lambda x: isinstance(x, P)):
        if any(ax is not None for ax in spec):
            return True
    return False


def constrain_server_batch(f, y, mesh: Optional[Mesh],
                           replicate: bool = False):
    """Pin the CycleSL server inner loop's minibatch layout on the mesh.

    Default (``replicate=False``): data-parallel — GSPMD propagates FSDP
    *weight* shardings into the resampled feature batches (the 'data'
    axis lands on d_model and the batch dim silently replicates — §Perf
    iteration 3); this pins the resampled (features, labels) minibatch
    instead: rows over 'data', and for >=3-d transformer features the
    model dim over 'model' (falling back to sequence sharding when the
    server batch doesn't divide 'data').  Replaces the old
    un-serializable ``CycleConfig.batch_constraint`` callable hook.

    ``replicate=True``: tensor-parallel — used when the server params
    themselves are FSDP/TP-sharded (:func:`params_are_sharded`).  Row-
    sharding the minibatch on the same axis as the weights would force
    GSPMD to all-gather the full weight matrix every scan step; with the
    minibatch replicated the contraction partials travel instead (an
    activation-sized all-reduce, orders of magnitude smaller than the
    weights) and the optimizer update stays 1/n_shards per device
    (§Weak scaling, ARCHITECTURE.md).
    """
    if mesh is None:
        return f, y
    if replicate:
        f = _wsc(f, mesh, P(*([None] * f.ndim)))
        y = jax.tree.map(
            lambda l: _wsc(l, mesh, P(*([None] * l.ndim))), y)
        return f, y
    d_ax = shard_if_divisible(f.shape[0], "data", mesh)
    m_ax = "model" if "model" in mesh.shape else None
    if f.ndim >= 3:              # [sb, S, ..., d] transformer features
        seq_ax = None if d_ax else shard_if_divisible(f.shape[1], "data",
                                                      mesh)
        dm_ax = shard_if_divisible(f.shape[-1], m_ax, mesh) if m_ax else None
        f = _wsc(f, mesh, P(d_ax, seq_ax, *([None] * (f.ndim - 3)), dm_ax))
    elif f.ndim == 2:
        f = _wsc(f, mesh, P(d_ax, None))
    y = jax.tree.map(
        lambda l: _wsc(l, mesh, P(d_ax, *([None] * (l.ndim - 1)))), y)
    return f, y


def cohort_shard_axes(mesh: Optional[Mesh], n_slots: int
                      ) -> Optional[tuple]:
    """Batch-axis tuple the [C, ...] cohort dim shards over, or None when
    there is no mesh / the dim doesn't divide the combined axis size.
    Mirrors :func:`batch_spec`'s axis choice ('pod','data' then 'data'
    alone)."""
    if mesh is None:
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if axes and n_slots % size == 0:
        return axes
    if "data" in mesh.shape and n_slots % mesh.shape["data"] == 0:
        return ("data",)
    return None


def shard_aligned_capacity(mesh: Optional[Mesh], capacity: int) -> int:
    """Round a cohort capacity up to a multiple of the batch-axis shard
    count so no shard runs under-filled (and :func:`batch_spec` never
    falls back to replicated).  Padded rounds are capacity-invariant
    (the PR 2 masking property), which is what makes this round-up
    numerically free.  Identity off-mesh and at 1 device."""
    if mesh is None:
        return capacity
    size = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            size *= mesh.shape[a]
    if size <= 1:
        return capacity
    return ((capacity + size - 1) // size) * size


def slot_shard_map(fn, mesh: Optional[Mesh], slot_args: tuple,
                   rep_args: tuple = ()):
    """Run a purely slot-wise cohort computation inside a ``shard_map``
    over the batch axes, so each device computes only its ``C /
    n_shards`` local slots.

    ``fn(*slot_args, *rep_args)`` must be embarrassingly parallel over
    the leading dim of every ``slot_args`` leaf (slot ``i`` of every
    output depends only on slot ``i`` of the inputs — the vmapped
    client-forward / per-client VJP / per-replica step shape).
    ``rep_args`` leaves are replicated to every shard.

    Why not leave it to GSPMD: the cohort-vmapped convolutions lower to
    ``feature_group_count=C`` grouped convs whose slot dim is folded
    into the channel dims; GSPMD has no partitioning rule for that
    fold, so it *replicates* the grouped conv on every device and then
    dynamic-slices out the local slot — 8 devices each do all 8 slots'
    work (§Weak scaling, ARCHITECTURE.md).  The manual shard_map makes
    the slot partition structural instead of inferred.

    Falls back to the plain call when there is no mesh, when C doesn't
    divide the batch-axis shard count (the Engine's shard-aligned
    capacity makes the divisible case the steady state), or when an
    activation mesh is registered (``set_activation_mesh``): the
    launcher's transformer/whisper stages constrain their own
    activations via ``constrain_batch``, and a named-axis constraint is
    illegal inside the manual region — those stacks keep the GSPMD
    path.  Per-slot math is unchanged, so the result is bit-for-bit the
    GSPMD path's.
    """
    if mesh is None or _ACTIVATION_MESH is not None:
        return fn(*slot_args, *rep_args)
    leaves = [l for l in jax.tree.leaves(slot_args)
              if hasattr(l, "ndim") and l.ndim >= 1]
    if not leaves:
        return fn(*slot_args, *rep_args)
    C = leaves[0].shape[0]
    axes = cohort_shard_axes(mesh, C)
    if axes is None:
        return fn(*slot_args, *rep_args)
    from jax.experimental.shard_map import shard_map
    lead = axes if len(axes) > 1 else axes[0]

    def sspec(l):
        return P(lead, *([None] * (l.ndim - 1)))

    def rspec(l):
        return P(*([None] * getattr(l, "ndim", 0)))

    out_shape = jax.eval_shape(lambda s, r: fn(*s, *r), slot_args, rep_args)
    wrapped = shard_map(
        lambda s, r: fn(*s, *r), mesh=mesh,
        in_specs=(jax.tree.map(sspec, slot_args),
                  jax.tree.map(rspec, rep_args)),
        out_specs=jax.tree.map(sspec, out_shape),
        check_rep=False)
    return wrapped(slot_args, rep_args)


def train_state_shardings(state, mesh: Mesh, moe_shard_mode: str = "expert",
                          shard_cohort: bool = True):
    """NamedSharding tree for a TrainState-like NamedTuple
    ``(server, clients, client_global)``.

    server / client_global — plain model entities, FSDP/TP per the path
    rules (role 'server' / 'full'); clients — the persistent [N, ...]
    per-client stack, leading cohort dim over the batch axes (role
    'client') unless ``shard_cohort`` is off.  Works on concrete states
    and on ``jax.eval_shape`` abstractions alike.
    """
    def _field(sub, role):
        if sub is None:
            return None
        return named_shardings(sub, mesh, role, moe_shard_mode)

    return type(state)(
        _field(state.server, "server"),
        _field(state.clients, "client" if shard_cohort else "full"),
        _field(state.client_global, "full"))


def constrain_stage(stage, mesh: Optional[Mesh], uses_global_client: bool):
    """Pin every field of a pipelined :class:`PipelineStage` to its
    canonical placement — the buffer-placement rule for the depth-L
    staleness ring.

    At depth 1 the single in-flight stage inherits a stable layout from
    the constraints inside the extract trace, but with L stages buffered
    the compiler is free to place each ring slot differently (the stage
    outlives several dispatch boundaries).  Constraining at the stage
    boundary keeps all L slots on ONE layout: cohort-stacked tensors
    (per-client entity stacks, smashed data, the pooled store rows) on
    the batch axes, the θ_S^t snapshot — and the un-broadcast global θ_C
    snapshot — on the FSDP/TP weight axes.  Value-neutral (layout only);
    no-op off-mesh.
    """
    if mesh is None:
        return stage
    clients = (constrain_entity_params(stage.clients, mesh, role="full")
               if uses_global_client
               else constrain_cohort_tree(stage.clients, mesh))
    store = stage.store
    if store is not None:
        from repro.core.feature_store import constrain_store
        store = constrain_store(store, mesh)
    return stage._replace(
        clients=clients,
        server_prev=constrain_entity_params(stage.server_prev, mesh),
        feats=(None if stage.feats is None
               else constrain_cohort_tree(stage.feats, mesh)),
        store=store)
