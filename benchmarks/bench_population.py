"""Population-scale scenario benchmark: 100k+ simulated clients, one
sharded server, heterogeneous/unreliable cohorts.

Each measurement drives the unmodified Engine over a
:class:`repro.scenario.population.PopulationFed` fleet (clients are
lazily materialized from a ``(seed, id)`` fold-in, so N=100 000 costs
nothing up front) under one churn scenario:

* ``no_churn``        — the null scenario (kind='none'): the baseline
                        every delta is taken against.
* ``dropout``         — uniform profiles, 15% per-round hazard: slots
                        drop MID-round (mask zeroed before ServerUpdate
                        consumes their features, commit skipped).
* ``straggler``       — pareto-straggler profiles: heavy-tailed compute,
                        lag beyond the staleness bound = deadline drop.
* ``straggler_async`` — same fleet under the async pipelined schedule,
                        where in-bound stragglers deliver against the
                        one-round-stale snapshot (realized lag <= 1).

Per scenario: rounds/sec (Engine collect_timing — device-synced, compile
round excluded), final eval accuracy + delta vs no_churn, churn
telemetry aggregates, and the compile-once claim (trace_count must stay
1 — churn is data through the attendance mask, never a retrace).

The device sweep mirrors bench_round: one fresh subprocess per count
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and an
``(N, 1)`` ('data', 'model') mesh.  Writes ``BENCH_population.json``
(CI runs ``--smoke --devices 1,8`` and uploads the artifact).

  PYTHONPATH=src python benchmarks/bench_population.py [--smoke]
      [--devices 1,8] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax

from repro.scenario.population import PopulationSpec, run_population
from repro.scenario.profiles import ScenarioConfig

N_CLIENTS = 100_000
COHORT = 32                 # divides every forced device count (1, 2, 4, 8)
BATCH = 8

SCENARIOS = {
    "no_churn": (ScenarioConfig(), {}),
    "dropout": (ScenarioConfig(kind="uniform", dropout=0.15), {}),
    "straggler": (ScenarioConfig(kind="pareto-straggler", straggler=1.0,
                                 staleness_bound=1), {}),
    "straggler_async": (ScenarioConfig(kind="pareto-straggler", straggler=1.0,
                                       staleness_bound=1),
                        {"pipeline_depth": 1, "pipeline_staleness": "async"}),
}


def population_worker(n_devices: int, smoke: bool) -> dict:
    """All scenarios at the CURRENT process's device count (the mesh is
    (N, 1) over ('data', 'model'); N=1 is the bit-for-bit unsharded
    baseline)."""
    rounds = 6 if smoke else 12
    spec = PopulationSpec(n_clients=N_CLIENTS)
    mesh = dict(mesh_shape=(n_devices, 1), mesh_axes=("data", "model"))
    rows, base_acc = {}, None
    for name, (scenario, overrides) in SCENARIOS.items():
        res = run_population(spec, scenario, cohort=COHORT, rounds=rounds,
                             batch=BATCH, **mesh, **overrides)
        acc = res["history"][-1]["accuracy"]
        if name == "no_churn":
            base_acc = acc
        tel = res.get("telemetry", {})
        rows[name] = {
            "rounds_per_sec": round(1.0 / res["round_time_s"], 2),
            "steady_ms": round(res["round_time_s"] * 1e3, 3),
            "final_accuracy": round(acc, 4),
            "accuracy_delta_vs_no_churn": round(acc - base_acc, 4),
            "trace_count": res["population"]["trace_count"],
            "clients_materialized": res["population"]["clients_materialized"],
            "live_cohort_mean": tel.get("live_cohort_mean"),
            "dropped_total": tel.get("dropped_total"),
            "drop_hazard_total": tel.get("drop_hazard_total"),
            "drop_deadline_total": tel.get("drop_deadline_total"),
            "max_realized_lag": tel.get("max_realized_lag"),
            "max_drawn_lag": tel.get("max_drawn_lag"),
        }
    return {
        "devices": n_devices,
        "jax_device_count": jax.device_count(),
        "n_clients": N_CLIENTS,
        "cohort_capacity": COHORT,
        "rounds": rounds,
        "scenarios": rows,
        "claims": {
            "compile_once_under_churn": all(
                r["trace_count"] == 1 for r in rows.values()),
            "lazy_fleet": max(r["clients_materialized"]
                              for r in rows.values()) <= COHORT * rounds * 2,
            "async_lag_bounded":
                rows["straggler_async"]["max_realized_lag"] <= 1,
        },
    }


def device_sweep(devices: list[int], smoke: bool) -> dict:
    """One fresh subprocess per device count (XLA_FLAGS must bind before
    jax initializes); the worker's JSON record is the last stdout line."""
    out = {}
    for n in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={n}"
                            ).strip()
        cmd = [sys.executable, os.path.abspath(__file__),
               "--population-worker", str(n)]
        if smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env)
        if proc.returncode != 0:
            out[str(n)] = {"error": proc.stderr[-2000:]}
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        out[str(n)] = rec
        for name, row in rec["scenarios"].items():
            print(f"[devices={n} {name}] "
                  f"rps={row['rounds_per_sec']} "
                  f"acc={row['final_accuracy']} "
                  f"(d={row['accuracy_delta_vs_no_churn']:+.4f}) "
                  f"dropped={row['dropped_total']} "
                  f"traces={row['trace_count']}")
    return out


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer rounds for CI (the fleet stays 100k)")
    ap.add_argument("--out", default="BENCH_population.json")
    ap.add_argument("--devices", default="1,8",
                    help="comma-separated forced-host device counts "
                         "(one subprocess per count)")
    ap.add_argument("--population-worker", type=int, default=None,
                    help=argparse.SUPPRESS)     # internal: one sweep point
    args = ap.parse_args()
    if args.population_worker is not None:
        print(json.dumps(population_worker(args.population_worker,
                                           args.smoke)))
        return {}
    result = {
        "backend": jax.default_backend(),
        "mode": "smoke" if args.smoke else "full",
        "n_clients": N_CLIENTS,
        "cohort": COHORT,
        "batch": BATCH,
        "device_sweep": device_sweep(
            [int(x) for x in args.devices.split(",")], args.smoke),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
