"""Resilience runtime benchmark: guard overhead + recovery latency.

Each measurement drives the unmodified Engine (synthetic image task,
padded cohorts) under one resilience scenario:

* ``guard_off``      — the null config: the baseline every delta is
                       taken against (no guard phase, no controller).
* ``guard_on``       — in-trace health guards armed, no faults: the
                       steady-state cost of the checks themselves (one
                       [4]-vector host sync per round; history must stay
                       bit-for-bit the guard_off run's).
* ``nan_quarantine`` — persistent poisoned deliveries (NaN features
                       every attempt): only excising the blamed slot via
                       the attendance mask saves the round.
* ``nan_retry``      — transient NaN deliveries recovered by re-running
                       the round from its pre-round state.
* ``nan_rollback``   — same faults recovered from the last-good
                       snapshot ring.
* ``dispatch_error`` — injected dispatch exceptions (preempted host)
                       absorbed by the retry policy, guard OFF — the
                       controller alone handles them.

Per scenario: rounds/sec (Engine collect_timing — device-synced, compile
round excluded), recovery latency per faulted round (mean round time
minus the guard_on baseline, amortized over the rounds that needed
recovery), telemetry totals, and the claims block (guard-on history
bit-for-bit, one trace per run, every faulted run completed).

The device sweep mirrors bench_population: one fresh subprocess per
count with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and an
``(N, 1)`` ('data', 'model') mesh.  Writes ``BENCH_resilience.json``
(CI runs ``--smoke --devices 1,8`` and uploads the artifact).

  PYTHONPATH=src python benchmarks/bench_resilience.py [--smoke]
      [--devices 1,8] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

N_CLIENTS = 32
ATTENDANCE = 0.25           # capacity 8: divides every forced count
BATCH = 8

def _scenarios():
    # built lazily: the worker imports repro only after XLA_FLAGS bind
    from repro.resilience import FaultConfig, ResilienceConfig
    return {
        "guard_off": ResilienceConfig(),
        "guard_on": ResilienceConfig(guard=True),
        "nan_quarantine": ResilienceConfig(
            guard=True, on_nonfinite="quarantine",
            faults=FaultConfig(nan_rate=0.3, persist=10)),
        "nan_retry": ResilienceConfig(
            guard=True, on_nonfinite="retry",
            faults=FaultConfig(nan_rate=0.3)),
        "nan_rollback": ResilienceConfig(
            guard=True, on_nonfinite="rollback",
            faults=FaultConfig(nan_rate=0.3)),
        "dispatch_error": ResilienceConfig(
            faults=FaultConfig(error_rate=0.3)),
    }


def resilience_worker(n_devices: int, smoke: bool) -> dict:
    """All scenarios at the CURRENT process's device count."""
    import jax

    from repro.api import Engine, ExperimentConfig

    rounds = 8 if smoke else 24
    rows, base = {}, {}
    for name, rcfg in _scenarios().items():
        cfg = ExperimentConfig(
            algo="cyclesfl", task="image", rounds=rounds,
            n_clients=N_CLIENTS, attendance=ATTENDANCE, min_cohort=2,
            batch=BATCH, eval_every=rounds, width=16, cut=1, seed=0,
            collect_timing=True, mesh_shape=(n_devices, 1),
            mesh_axes=("data", "model"), resilience=rcfg)
        eng = Engine(cfg, log=lambda *a: None)
        res = eng.run()
        tel = res.get("resilience", {})
        rt = res["round_time_s"]
        if name in ("guard_off", "guard_on"):
            base[name] = {"rt": rt, "history": [
                {k: v for k, v in r.items() if k != "elapsed_s"}
                for r in res["history"]]}
        faulted = tel.get("faulted_rounds", 0)
        # extra wall-clock the recovery work cost, amortized over the
        # rounds that needed it (vs the armed-but-clean baseline)
        lat = (None if not faulted or "guard_on" not in base
               else max(0.0, (rt - base["guard_on"]["rt"]) * rounds
                        / faulted))
        rows[name] = {
            "rounds_per_sec": round(1.0 / rt, 2),
            "steady_ms": round(rt * 1e3, 3),
            "recovery_latency_ms_per_faulted_round":
                None if lat is None else round(lat * 1e3, 3),
            "faulted_rounds": faulted,
            "retries": tel.get("retries", 0),
            "rollbacks": tel.get("rollbacks", 0),
            "quarantine_events": tel.get("quarantine_events", 0),
            "quarantined_clients": len(tel.get("quarantined_clients", [])),
            "trace_count": eng.algo.trace_count,
        }
    off, on = base["guard_off"], base["guard_on"]
    return {
        "devices": n_devices,
        "jax_device_count": jax.device_count(),
        "rounds": rounds,
        "scenarios": rows,
        "guard_overhead_pct": round(
            (off["rt"] and (on["rt"] - off["rt"]) / off["rt"]) * 100, 2),
        "claims": {
            "guard_on_bit_for_bit": on["history"] == off["history"],
            "compile_once": all(r["trace_count"] == 1
                                for r in rows.values()),
            "all_faulted_runs_recovered": all(
                r["faulted_rounds"] > 0 for n, r in rows.items()
                if n not in ("guard_off", "guard_on")),
        },
    }


def device_sweep(devices: list[int], smoke: bool) -> dict:
    """One fresh subprocess per device count (XLA_FLAGS must bind before
    jax initializes); the worker's JSON record is the last stdout line."""
    out = {}
    for n in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={n}"
                            ).strip()
        cmd = [sys.executable, os.path.abspath(__file__),
               "--resilience-worker", str(n)]
        if smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env)
        if proc.returncode != 0:
            out[str(n)] = {"error": proc.stderr[-2000:]}
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        out[str(n)] = rec
        print(f"[devices={n}] guard overhead "
              f"{rec['guard_overhead_pct']:+.2f}% claims={rec['claims']}")
        for name, row in rec["scenarios"].items():
            print(f"[devices={n} {name}] rps={row['rounds_per_sec']} "
                  f"faulted={row['faulted_rounds']} "
                  f"lat_ms={row['recovery_latency_ms_per_faulted_round']} "
                  f"traces={row['trace_count']}")
    return out


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer rounds for CI")
    ap.add_argument("--out", default="BENCH_resilience.json")
    ap.add_argument("--devices", default="1,8",
                    help="comma-separated forced-host device counts "
                         "(one subprocess per count)")
    ap.add_argument("--resilience-worker", type=int, default=None,
                    help=argparse.SUPPRESS)     # internal: one sweep point
    args = ap.parse_args()
    if args.resilience_worker is not None:
        print(json.dumps(resilience_worker(args.resilience_worker,
                                           args.smoke)))
        return {}
    import jax
    result = {
        "backend": jax.default_backend(),
        "mode": "smoke" if args.smoke else "full",
        "n_clients": N_CLIENTS,
        "attendance": ATTENDANCE,
        "batch": BATCH,
        "device_sweep": device_sweep(
            [int(x) for x in args.devices.split(",")], args.smoke),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
