"""Benchmark harness — one function per paper table + kernel microbenches.

Prints ``name,us_per_call,derived`` CSV (derived = the table's headline
number or claim check).  ``--fast`` (default when run as module in CI)
uses reduced rounds; ``--full`` runs the paper-shaped versions.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table3,...]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def bench_kernels() -> list[tuple[str, float, str]]:
    """Pallas kernels vs their jnp oracles (interpret mode on CPU)."""
    from repro.kernels import ref
    from repro.kernels.ops import (feature_resample, flash_attention,
                                   ssd_scan, topk_gating)
    rows = []
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    t_k = _time_fn(lambda: flash_attention(q, k, v))
    t_r = _time_fn(jax.jit(lambda: ref.flash_attention_ref(q, k, v)))
    rows.append(("kernel_flash_attention", t_k, f"ref_us={t_r:.0f}"))

    x = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.normal(size=(1, 256, 2)), jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.normal(size=(2,)), jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(1, 256, 2, 16)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(1, 256, 2, 16)), jnp.float32)
    t_k = _time_fn(lambda: ssd_scan(x, dt, A, Bm, Cm, chunk=64))
    t_r = _time_fn(jax.jit(lambda: ref.ssd_scan_ref(x, dt, A, Bm, Cm)[0]))
    rows.append(("kernel_ssd_scan", t_k, f"ref_us={t_r:.0f}"))

    logits = jnp.asarray(rng.normal(size=(1024, 64)), jnp.float32)
    t_k = _time_fn(lambda: topk_gating(logits, 8))
    t_r = _time_fn(jax.jit(lambda: ref.topk_gating_ref(logits, 8)))
    rows.append(("kernel_topk_gating", t_k, f"ref_us={t_r:.0f}"))

    src = jnp.asarray(rng.normal(size=(1024, 256)), jnp.float32)
    idx = jnp.asarray(rng.permutation(1024)[:512], jnp.int32)
    t_k = _time_fn(lambda: feature_resample(src, idx))
    t_r = _time_fn(jax.jit(lambda: ref.feature_resample_ref(src, idx)))
    rows.append(("kernel_feature_resample", t_k, f"ref_us={t_r:.0f}"))
    return rows


def bench_cyclesl_round() -> list[tuple[str, float, str]]:
    """Wall time of one jitted CycleSL round vs baselines (CPU, tiny)."""
    from benchmarks.common import BenchConfig, build, experiment_config
    from repro.api import Engine
    bc = BenchConfig(width=8)
    task, fed = build(bc, 0)
    rows = []
    for name in ("sflv2", "cyclesfl"):
        # donate=False: the timing loop re-feeds the same state buffers
        eng = Engine(experiment_config(bc, name, 0), task=task, fed=fed,
                     metric_key="accuracy", donate=False,
                     log=lambda *a, **k: None)
        state = eng.init_state()
        rng = np.random.default_rng(0)
        cohort, xs, ys, mask = eng.sample_round(rng)
        key = eng.round_key(1)
        t = _time_fn(
            lambda: eng.algo.round(state, cohort, xs, ys, key,
                                   mask)[1]["server_loss"],
            iters=3, warmup=1)
        live = len(cohort) if mask is None else int(mask.sum())
        rows.append((f"round_{name}", t,
                     f"cohort={live}/cap={len(cohort)}"))
    return rows


def bench_tables(fast: bool, only: set[str] | None) -> list[tuple[str, float, str]]:
    rows = []
    specs = [
        ("table3", "benchmarks.table3_accuracy"),
        ("table4", "benchmarks.table4_cutlayer"),
        ("table5", "benchmarks.table5_serverepoch"),
        ("table6", "benchmarks.table6_gradnorm"),
        ("table8", "benchmarks.table8_latency"),
    ]
    import importlib
    os.makedirs("benchmarks/results", exist_ok=True)
    for name, mod_name in specs:
        if only and name not in only:
            continue
        mod = importlib.import_module(mod_name)
        t0 = time.time()
        out = mod.main(fast=fast)
        dt = (time.time() - t0) * 1e6
        with open(f"benchmarks/results/{name}.json", "w") as f:
            json.dump(out, f, indent=1)
        claims = out.get("claims", {})
        derived = ";".join(f"{k}={v}" for k, v in claims.items()) or "see_json"
        rows.append((name, dt, derived))
    return rows


def bench_roofline(only) -> list[tuple[str, float, str]]:
    """Summarize the dry-run roofline table if the sweep artifact exists."""
    path = "benchmarks/results/dryrun_final.json"
    if not os.path.exists(path):
        path = "benchmarks/results/dryrun.json"
    if not os.path.exists(path) or (only and "roofline" not in only):
        return []
    from repro.launch.roofline import analyze_record
    with open(path) as f:
        recs = json.load(f)
    rows = []
    n_ok = 0
    doms = {}
    for rec in recs:
        a = analyze_record(rec)
        if a:
            n_ok += 1
            doms[a["dominant"]] = doms.get(a["dominant"], 0) + 1
    rows.append(("roofline_dryrun", 0.0,
                 f"ok={n_ok};dominant={json.dumps(doms).replace(' ', '')}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: kernels,round,table3..table8,roofline")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None

    rows = []
    if only is None or "kernels" in only:
        rows += bench_kernels()
    if only is None or "round" in only:
        rows += bench_cyclesl_round()
    rows += bench_tables(fast=not args.full, only=only)
    rows += bench_roofline(only)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
