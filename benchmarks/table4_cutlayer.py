"""Paper Table 4 analog: impact of the cut layer on CycleSFL accuracy
(ResNet9, 6 possible block-wise cut positions).

Paper claim validated: shallower cuts perform better for CycleSL —
client-side complexity is where drift lives, so a smaller client part
converges better.
"""
from __future__ import annotations

import json

from benchmarks.common import BenchConfig, aggregate, run_algo


def run(cuts=(1, 2, 3, 4, 5, 6), bc: BenchConfig | None = None) -> dict:
    base = bc or BenchConfig(model="resnet9", width=8, rounds=40,
                             n_classes=10, seeds=(0,))
    table = {}
    for cut in cuts:
        runs = [run_algo(base.__class__(**{**base.__dict__, "cut": cut}),
                         "cyclesfl", s) for s in base.seeds]
        m, s = aggregate(runs, "final_acc")
        table[cut] = {"acc_mean": m, "acc_std": s}
    accs = [table[c]["acc_mean"] for c in cuts]
    return {"table": table,
            "claims": {"shallow_beats_deep": accs[0] > accs[-1]}}


def main(fast: bool = False):
    cuts = (1, 3, 6) if fast else (1, 2, 3, 4, 5, 6)
    bc = BenchConfig(model="resnet9", width=8, n_classes=10,
                     rounds=25 if fast else 40, seeds=(0,))
    out = run(cuts, bc)
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
