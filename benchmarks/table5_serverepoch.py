"""Paper Table 5 analog: impact of server epochs E on CycleSFL.

Paper claim validated: E>1 helps under strong heterogeneity (small
Dirichlet alpha); under mild heterogeneity returns diminish/overfit.
"""
from __future__ import annotations

import dataclasses
import json

from benchmarks.common import BenchConfig, aggregate, run_algo


def run(epochs=(1, 2, 4, 8), alphas=(1.0, 0.1),
        bc: BenchConfig | None = None) -> dict:
    base = bc or BenchConfig(rounds=40, seeds=(0,))
    table = {}
    for alpha in alphas:
        for e in epochs:
            b = dataclasses.replace(base, alpha=alpha, server_epochs=e) \
                if dataclasses.is_dataclass(base) else base
            runs = [run_algo(b, "cyclesfl", s) for s in base.seeds]
            m, s = aggregate(runs, "final_acc")
            table[f"alpha={alpha},E={e}"] = {"acc_mean": m, "acc_std": s}
    return {"table": table}


def main(fast: bool = False):
    out = run(epochs=(1, 4) if fast else (1, 2, 4, 8),
              alphas=(0.1,) if fast else (1.0, 0.1),
              bc=BenchConfig(rounds=25 if fast else 40, seeds=(0,)))
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
