"""Paper Table 3 + Table 14 analog: test metrics + convergence speed for
the seven SL algorithms on the synthetic non-iid federated image task.

Paper claim validated: cycle-version methods outperform their originals
(CyclePSL>PSL, CycleSGLR>SGLR, CycleSFL>SFLV1) and CycleSFL ≳ SFLV2;
cycle versions reach the accuracy threshold in fewer rounds.
"""
from __future__ import annotations

import json

from benchmarks.common import BenchConfig, aggregate, run_algo


def run(bc: BenchConfig | None = None) -> dict:
    bc = bc or BenchConfig()
    table = {}
    for algo in bc.algos:
        runs = [run_algo(bc, algo, s) for s in bc.seeds]
        acc_m, acc_s = aggregate(runs, "final_acc")
        best_m, best_s = aggregate(runs, "best_acc")
        loss_m, loss_s = aggregate(runs, "final_loss")
        rtt_m, _ = aggregate(runs, "rounds_to_threshold")
        table[algo] = {"acc_mean": acc_m, "acc_std": acc_s,
                       "best_mean": best_m, "best_std": best_s,
                       "loss_mean": loss_m, "loss_std": loss_s,
                       "rounds_to_threshold": rtt_m}

    def rtt(a):
        v = table[a]["rounds_to_threshold"]
        return v if v == v else float("inf")   # NaN -> never reached

    # Primary claims: paper Table 14 (convergence speed) — the robust
    # effect at miniature scale; plus Table 3's PSL-pair accuracy gap.
    checks = {
        "rtt_cyclepsl<=psl": rtt("cyclepsl") <= rtt("psl"),
        "rtt_cyclesglr<=sglr": rtt("cyclesglr") <= rtt("sglr"),
        "rtt_cyclesfl<=sflv1": rtt("cyclesfl") <= rtt("sflv1"),
        "best_cyclepsl>psl": table["cyclepsl"]["best_mean"] > table["psl"]["best_mean"],
        "acc_cyclesfl_vs_sflv1_gap": table["cyclesfl"]["acc_mean"]
        - table["sflv1"]["acc_mean"],
    }
    return {"table": table, "claims": checks}


def main(fast: bool = False):
    bc = BenchConfig(rounds=30 if fast else 60,
                     seeds=(0,) if fast else (0, 1))
    out = run(bc)
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
