"""Paper Table 6 analog: gradient-stability (norm mean/std of the
gradients the server sends back to clients) per algorithm.

Paper claim validated: cycle-version methods yield lower-magnitude,
lower-variance returned gradients than their originals.
"""
from __future__ import annotations

import json

from benchmarks.common import BenchConfig, run_algo


def run(bc: BenchConfig | None = None) -> dict:
    bc = bc or BenchConfig(rounds=30, seeds=(0,))
    table = {}
    for algo in bc.algos:
        r = run_algo(bc, algo, bc.seeds[0])
        table[algo] = r["grad_stability"]
    claims = {
        "cyclepsl_lower_norm": (table["cyclepsl"]["grad_norm_mean"]
                                < table["psl"]["grad_norm_mean"]),
        "cyclesfl_lower_norm": (table["cyclesfl"]["grad_norm_mean"]
                                < table["sflv1"]["grad_norm_mean"]),
    }
    return {"table": table, "claims": claims}


def main(fast: bool = False):
    out = run(BenchConfig(rounds=15 if fast else 30, seeds=(0,)))
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
