"""Paper Table 8 analog: server-side processing cost per round.

Paper claim validated (ordering, not absolute seconds): SFLV2 is the
cheapest (single pass), SFLV1 pays for replica aggregation, CycleSFL
is the most expensive (smashed data passes the server twice + E-epoch
inner loop) — the paper's stated latency trade-off (§5.2).

We report both wall-clock round time on CPU and an analytic
server-FLOPs ratio (forward-equivalent passes over the round's tokens).
"""
from __future__ import annotations

import json

from benchmarks.common import BenchConfig, run_algo


#   server fwd-equivalents per round (fwd=1, bwd=2):
#   SFLV2: fwd+bwd once over all cohort data            = 3
#   SFLV1: same compute + replica-average overhead      = 3 (+agg)
#   CycleSFL (E=1): inner loop fwd+bwd (3) + frozen fwd+feature-bwd (3)= 6
ANALYTIC_PASSES = {"sflv1": 3, "sflv2": 3, "cyclesfl": 6}


def run(bc: BenchConfig | None = None) -> dict:
    bc = bc or BenchConfig(rounds=12, seeds=(0,),
                           algos=("sflv1", "sflv2", "cyclesfl"))
    table = {}
    for algo in bc.algos:
        r = run_algo(bc, algo, bc.seeds[0], collect_timing=True)
        table[algo] = {"round_time_s": r["round_time_s"],
                       "analytic_server_passes": ANALYTIC_PASSES.get(algo)}
    # NOTE: wall-clock ordering on CPU can invert vs the paper's GPU
    # numbers because SFLV2's sequential scan doesn't vectorize while
    # CycleSFL's phases do; the paper's Table 8 claim is about server
    # COMPUTE, which the analytic pass count captures exactly.
    claims = {
        "cyclesfl_server_compute_exceeds_sflv2":
            ANALYTIC_PASSES["cyclesfl"] > ANALYTIC_PASSES["sflv2"],
        "wallclock_cyclesfl_gt_sflv2_cpu":
            table["cyclesfl"]["round_time_s"] > table["sflv2"]["round_time_s"],
    }
    return {"table": table, "claims": claims}


def main(fast: bool = False):
    out = run(BenchConfig(rounds=6 if fast else 12, seeds=(0,),
                          algos=("sflv1", "sflv2", "cyclesfl")))
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
