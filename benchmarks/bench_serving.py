"""Serving benchmark: continuous-batching throughput/latency sweep.

Drives ONE :class:`repro.serve.ServeRuntime` (gemma2-2b smoke arch)
through a closed-loop concurrency sweep — the load generator keeps
exactly ``c`` client streams outstanding per point — and records
throughput (tokens/s, requests/s) plus latency and time-to-first-token
percentiles per concurrent-client count into ``BENCH_serving.json``.

Sharing one runtime across the whole sweep is the point: the trace
counters span every arrival pattern the sweep produces, so the record's
``compile_once`` claim ("one jitted prefill/admit/decode trace total")
is measured, not asserted.  Two more tracked claims ride along:

* ``deadline_honored`` — no completed request finished past its
  deadline, and a probe batch submitted with an already-expired
  deadline is rejected/evicted without producing tokens;
* ``slot_reuse`` — at least one slot served multiple requests (the
  fixed table actually recycles).

CI (the ``serving`` leg) runs ``--smoke``, gates on the claims, and
uploads the artifact.

  PYTHONPATH=src python benchmarks/bench_serving.py [--smoke]
      [--concurrency 1,2,4,8] [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests per sweep point for CI")
    ap.add_argument("--concurrency", default="1,2,4,8",
                    help="comma-separated concurrent-client counts")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    import jax

    from repro.configs.gemma2_2b import smoke
    from repro.serve import (ServeConfig, ServeRuntime, STATUS_DONE,
                             make_prompts, run_closed_loop)

    arch = smoke()
    counts = [int(c) for c in args.concurrency.split(",")]
    sc = ServeConfig(slots=max(counts), max_prompt_len=8,
                     max_new_tokens=8, prefill_batch=min(4, max(counts)),
                     deadline_s=600.0)
    rt = ServeRuntime(arch, sc, seed=0)
    per_point = 4 if args.smoke else 16

    rows = []
    for i, c in enumerate(counts):
        prompts = make_prompts(c * per_point, sc.max_prompt_len,
                               arch.vocab, seed=10 + i)
        row = run_closed_loop(rt, prompts, concurrency=c)
        row["latency_ms"] = {k: (None if v is None else round(v * 1e3, 3))
                             for k, v in row.pop("latency_s").items()}
        row["ttft_ms"] = {k: (None if v is None else round(v * 1e3, 3))
                          for k, v in row.pop("ttft_s").items()}
        row["throughput_tok_s"] = round(row["throughput_tok_s"], 2)
        row["throughput_req_s"] = round(row["throughput_req_s"], 2)
        row["elapsed_s"] = round(row["elapsed_s"], 4)
        rows.append(row)
        print(f"[c={c}] tok/s={row['throughput_tok_s']} "
              f"p50={row['latency_ms']['p50']}ms "
              f"p99={row['latency_ms']['p99']}ms "
              f"done={row['by_status'][STATUS_DONE]}/{row['n_requests']}")

    # deadline probes: an effectively-expired deadline must never yield
    # a completed request (queued ones are rejected before any compute)
    probe_rids = [rt.submit([1 + i], deadline_s=1e-9) for i in range(4)]
    rt.drain()
    probes_blocked = all(rt.results[r].status != STATUS_DONE
                         for r in probe_rids)
    done = [r for r in rt.results.values() if r.status == STATUS_DONE]
    stats = rt.stats()
    claims = {
        "compile_once": stats["traces"] == {"prefill": 1, "admit": 1,
                                            "decode": 1},
        "deadline_honored": (probes_blocked and bool(done)
                             and all(r.finished <= r.deadline
                                     for r in done)),
        "slot_reuse": stats["max_slot_reuse"] > 1,
    }
    result = {
        "backend": jax.default_backend(),
        "mode": "smoke" if args.smoke else "full",
        "arch": arch.name,
        "serve": sc.to_dict(),
        "requests_per_client": per_point,
        "sweep": rows,
        "traces": stats["traces"],
        "max_slot_reuse": stats["max_slot_reuse"],
        "evictions": stats["evictions"],
        "claims": claims,
    }
    print(f"claims={claims}")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
