"""Round-latency benchmark: compile count, steady-state latency,
rounds/sec — the evidence behind the compile-once contract.

The Engine pads every cohort to the static capacity C_max and threads an
attendance mask through the jitted round, so ONE XLA trace serves every
live cohort size the protocol produces.  This harness measures, per
algorithm:

* ``padded``            — variable attendance, fixed shapes: compile
                          count (must be 1), steady-state round latency,
                          rounds/sec.
* ``unpadded_variable`` — the same variable-attendance stream without
                          padding: one retrace per distinct cohort size
                          (what wall-clock used to be dominated by).
* ``fixed_size_comparison`` — padded vs the legacy unpadded path at a
                          FIXED cohort size, interleaved measurement:
                          the steady-state baseline the padded path
                          must not regress against.
* ``by_cohort_size``    — padded rounds/sec across capacities.
* ``pipeline_comparison`` — (``--pipeline``) rounds/sec with the
                          pipelined scheduler off vs sync-barrier vs
                          async bounded-stale overlap at each ring depth
                          in ``--pipeline-depths`` (default 0,1,2,4),
                          per algorithm, with the trace-budget,
                          per-depth bounded-lag, and staleness-weighting
                          identity claims.
* ``device_sweep``      — (``--devices 1,2,4,8``) the weak-scaling
                          sweep: rounds/sec of the sharded Engine vs
                          device count at FIXED GLOBAL WORK, on the
                          pinned client-heavy cut=3 config, through the
                          device-resident run loop (donated buffers,
                          prefetch, sync_every).  Each count runs in a
                          fresh subprocess with
                          ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
                          (jax locks the device count at first init).
                          Per point: steady latency, the fused
                          gather+loss-inside-shard_map variant, a
                          per-phase cost breakdown, and the collective
                          census with the no-pool-allgather HLO
                          assertion.  The sweep-level claim is
                          ``weak_scaling_efficiency`` = rps(max devices)
                          / rps(1 device) >= 1.0.
* ``shard_local``       — (``--shard-local [1,8]``) the sharded Engine
                          with ``cycle.shard_local_resample`` off vs on,
                          interleaved measurement per device count (one
                          subprocess each): the off/on steady-state
                          comparison behind the shard_map resample path,
                          plus the loss-equality claim (the two paths
                          must agree — shard-local is value-exact).

Writes ``BENCH_round_latency.json`` so every PR records the perf
trajectory (CI runs ``--smoke --devices 1,2,4`` and uploads the
artifact).

  PYTHONPATH=src python benchmarks/bench_round.py [--smoke] [--out PATH]
      [--devices 1,2,4,8] [--pipeline]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from dataclasses import replace

import jax
import numpy as np

from repro.api import Engine, ExperimentConfig
from repro.core.cyclesl import CycleConfig

ALGOS = ("psl", "cyclepsl", "cyclesfl")


def _drive(eng: Engine, rounds: int) -> list[float]:
    """Run ``rounds`` rounds through the Engine's sampling protocol and
    return per-round wall times (device-synced)."""
    state = eng.init_state()
    rng = np.random.default_rng(eng.cfg.seed + 1)
    times = []
    for rnd in range(rounds):
        cohort, xs, ys, mask = eng.sample_round(rng)
        t0 = time.perf_counter()
        if mask is None:
            state, m = eng.algo.round(state, cohort, xs, ys,
                                      eng.round_key(rnd))
        else:
            state, m = eng.algo.round(state, cohort, xs, ys,
                                      eng.round_key(rnd), mask)
        jax.block_until_ready(m["server_loss"])
        times.append(time.perf_counter() - t0)
    return times


def _steady(times: list[float], warmup: int = 2) -> float:
    tail = times[warmup:] or times
    return float(np.median(tail))


def _engine(cfg: ExperimentConfig) -> Engine:
    return Engine(cfg, donate=False, log=lambda *a, **k: None)


def _round_call(eng: Engine):
    """A zero-sampling-cost round closure over one drawn cohort."""
    state = eng.init_state()
    rng = np.random.default_rng(eng.cfg.seed + 1)
    cohort, xs, ys, mask = eng.sample_round(rng)
    key = eng.round_key(0)
    if mask is None:
        return lambda: eng.algo.round(state, cohort, xs, ys,
                                      key)[1]["server_loss"]
    return lambda: eng.algo.round(state, cohort, xs, ys, key,
                                  mask)[1]["server_loss"]


def _interleaved(call_a, call_b, iters: int) -> tuple[float, float]:
    """Median wall time of two compiled calls, alternated every
    iteration — and with the within-pair ORDER alternated too, so CPU
    frequency/cache drift and first-in-pair warmup bias hit both
    equally."""
    for call in (call_a, call_b):                   # compile + warm
        jax.block_until_ready(call())
        jax.block_until_ready(call())
    ta, tb = [], []
    for i in range(iters):
        first, second, tf, ts = ((call_a, call_b, ta, tb) if i % 2 == 0
                                 else (call_b, call_a, tb, ta))
        t0 = time.perf_counter()
        jax.block_until_ready(first())
        tf.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(second())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ta)), float(np.median(tb))


def bench_algo(algo: str, base: ExperimentConfig, rounds: int,
               capacities: tuple[int, ...]) -> dict:
    out = {}

    # 1. padded + variable attendance: the compile-once path
    eng = _engine(replace(base, algo=algo, variable_attendance=True,
                          pad_cohorts=True))
    times = _drive(eng, rounds)
    out["padded"] = {
        "compile_count": eng.algo.trace_count,
        "first_round_s": round(times[0], 4),
        "steady_ms": round(_steady(times) * 1e3, 3),
        "rounds_per_sec": round(1.0 / _steady(times), 2),
        "cohort_capacity": eng.cohort_capacity,
    }

    # 2. same variable-attendance stream, no padding: one retrace per
    #    distinct live cohort size
    eng = _engine(replace(base, algo=algo, variable_attendance=True,
                          pad_cohorts=False))
    times = _drive(eng, rounds)
    out["unpadded_variable"] = {
        "compile_count": eng.algo.trace_count,
        "total_s": round(sum(times), 3),
        "steady_ms": round(_steady(times) * 1e3, 3),
    }

    # 3. steady-state at a FIXED cohort size == capacity, padded vs the
    #    legacy unpadded path, interleaved so timer drift is shared:
    #    this is the "padding costs nothing once shapes are stable" claim
    eng_pad = _engine(replace(base, algo=algo, variable_attendance=False,
                              pad_cohorts=True))
    eng_fix = _engine(replace(base, algo=algo, variable_attendance=False,
                              pad_cohorts=False))
    pad_ms, fix_ms = _interleaved(_round_call(eng_pad), _round_call(eng_fix),
                                  iters=max(20, rounds))
    out["fixed_size_comparison"] = {
        "padded_steady_ms": round(pad_ms * 1e3, 3),
        "unpadded_steady_ms": round(fix_ms * 1e3, 3),
        "padded_over_unpadded": round(pad_ms / fix_ms, 3),
    }

    # 4. padded rounds/sec across cohort capacities
    by_size = {}
    for cap in capacities:
        att = cap / base.n_clients
        eng = _engine(replace(base, algo=algo, attendance=att,
                              variable_attendance=True, pad_cohorts=True))
        times = _drive(eng, max(4, rounds // 2))
        by_size[str(eng.cohort_capacity)] = {
            "steady_ms": round(_steady(times) * 1e3, 3),
            "rounds_per_sec": round(1.0 / _steady(times), 2),
            "compile_count": eng.algo.trace_count,
        }
    out["by_cohort_size"] = by_size

    out["claims"] = {
        "compile_once": out["padded"]["compile_count"] == 1,
        "unpadded_retraces_exceed_one":
            out["unpadded_variable"]["compile_count"] > 1,
        # steady-state: padded must not regress vs the legacy fixed-size
        # path (10% slack absorbs residual CPU timer noise at ms scale)
        "padded_steady_no_worse_than_unpadded_fixed":
            out["fixed_size_comparison"]["padded_over_unpadded"] <= 1.10,
    }
    return out


# ----------------------------------------------------- pipeline sweep
class _LossTrail:
    """Per-round server_loss recorder (for the weighting-identity claim)."""

    def __init__(self):
        self.vals = []

    def on_round(self, engine, rnd, state, metrics):
        self.vals.append(np.asarray(metrics["server_loss"]))


def pipeline_sweep(smoke: bool, depths: tuple = (0, 1, 2, 4)) -> dict:
    """Rounds/sec with the pipelined scheduler off vs on across ring
    depths (sync barrier + async bounded-stale overlap at each depth in
    ``depths``), per algorithm — the evidence behind the pipeline_depth
    knob.  Timing goes through the Engine's own collect_timing path
    (device-synced per round, compile round excluded), so what's
    measured is the schedule, not the harness.  Also runs the
    staleness-weighting identity check: a sync schedule (lag 0 every
    round) with ``staleness_weighting='inverse'`` must reproduce the
    unweighted sync run's per-round server_loss bit-for-bit."""
    base = ExperimentConfig(
        task="image", n_clients=24 if smoke else 60,
        attendance=0.25 if smoke else 0.2, batch=8 if smoke else 16,
        width=4 if smoke else 8, cut=2, seed=0, eval_every=10**9,
        rounds=8 if smoke else 16, collect_timing=True)
    async_depths = sorted(d for d in set(depths) if d >= 1)
    sync_depth = async_depths[0] if async_depths else 1
    modes = {"off": {"pipeline_depth": 0},
             "sync": {"pipeline_depth": sync_depth,
                      "pipeline_staleness": "sync"}}
    for d in async_depths:
        modes[f"async{d}"] = {"pipeline_depth": d,
                              "pipeline_staleness": "async"}
    out = {"depths": list(depths)}
    for algo in ALGOS:
        rec = {}
        sync_losses = None
        for mode, kw in modes.items():
            trail = _LossTrail()
            eng = Engine(replace(base, algo=algo, **kw), donate=False,
                         callbacks=(trail,), log=lambda *a, **k: None)
            res = eng.run()
            entry = {
                "depth": kw["pipeline_depth"],
                "steady_ms": round(res["round_time_s"] * 1e3, 3),
                "rounds_per_sec": round(1.0 / res["round_time_s"], 2),
            }
            if mode != "off":
                entry["extract_traces"] = eng.pipeline.extract_traces
                entry["tail_traces"] = eng.pipeline.tail_traces
                entry["max_theta_s_lag_rounds"] = \
                    res["pipeline"]["max_theta_s_lag_rounds"]
                entry["realized_lags"] = res["pipeline"]["realized_lags"]
            else:
                entry["compile_count"] = eng.algo.trace_count
            if mode == "sync":
                sync_losses = trail.vals
            rec[mode] = entry
        # weighting identity: sync + inverse weighting == sync unweighted
        # up to XLA fusion (w(0) is exactly 1.0, but the traced multiply
        # can reassociate downstream reductions by an ulp)
        trail_w = _LossTrail()
        Engine(replace(base, algo=algo, pipeline_depth=sync_depth,
                       staleness_weighting="inverse"), donate=False,
               callbacks=(trail_w,), log=lambda *a, **k: None).run()
        weighting_identity = (
            len(trail_w.vals) == len(sync_losses)
            and all(np.allclose(a, b, rtol=1e-5, atol=1e-7)
                    for a, b in zip(sync_losses, trail_w.vals)))
        pipe_modes = [m for m in rec if m != "off"]
        rec["claims"] = {
            # one extract + one tail trace — the "at most one warm-up
            # trace over the sequential budget" acceptance, at EVERY depth
            "pipeline_trace_budget": all(
                rec[m]["extract_traces"] == 1 and rec[m]["tail_traces"] == 1
                for m in pipe_modes),
            # async lag never exceeds the configured ring depth; sync is
            # lag-free whatever the depth says
            "depth_lag_bounded": {
                m: rec[m]["max_theta_s_lag_rounds"] <= rec[m]["depth"]
                for m in pipe_modes if m.startswith("async")},
            "sync_lag_zero": rec["sync"]["max_theta_s_lag_rounds"] == 0,
            "weighting_identity_at_none": weighting_identity,
            "sync_over_off":
                round(rec["sync"]["steady_ms"]
                      / rec["off"]["steady_ms"], 3),
            **{f"{m}_over_off":
               round(rec[m]["steady_ms"] / rec["off"]["steady_ms"], 3)
               for m in pipe_modes if m.startswith("async")},
            # the pipelined schedule must cost ~nothing even where it
            # cannot win: on a single-core host the two dispatches
            # serialize, so the bound is "no duplicated boundary
            # traffic", not "overlap speedup".  (The historical 1.44x
            # cyclepsl regression was the PipelineStage carrying the
            # cohort features twice — raw [C, b, ...] AND pooled — and
            # is fixed by the store-only handoff.)  Deeper rings add
            # only host-side bookkeeping per round, so they get the
            # same bound with a little extra timer slack.
            "async_overhead_bounded": all(
                rec[m]["steady_ms"] / rec["off"]["steady_ms"]
                <= (1.15 if rec[m]["depth"] <= 1 else 1.25)
                for m in pipe_modes if m.startswith("async")),
        }
        out[algo] = rec
        async_ms = " ".join(
            f"{m}={rec[m]['steady_ms']}ms(lag {rec[m]['max_theta_s_lag_rounds']})"
            for m in pipe_modes if m.startswith("async"))
        print(f"[pipeline {algo}] off={rec['off']['steady_ms']}ms "
              f"sync={rec['sync']['steady_ms']}ms {async_ms} "
              f"weighting_identity={weighting_identity}")
    return out


# -------------------------------------------------- shard-local sweep
def shard_local_worker(n_devices: int, smoke: bool) -> dict:
    """Shard-local resample off vs on at the CURRENT process's device
    count, interleaved so timer drift hits both paths equally.  The two
    runs share config, mesh, and cohort stream — only
    ``cycle.shard_local_resample`` differs — and must produce the same
    server loss (the path is value-exact)."""
    base = ExperimentConfig(
        algo="cyclesfl", task="image", rounds=1, n_clients=32,
        attendance=0.25, batch=8, width=4 if smoke else 8, cut=2, seed=0,
        eval_every=10**9, mesh_shape=(n_devices, 1),
        mesh_axes=("data", "model"),
        cycle=CycleConfig(server_epochs=2))
    eng_off = _engine(base)
    eng_on = _engine(base.with_cycle(shard_local_resample=True))
    off_ms, on_ms = _interleaved(_round_call(eng_off), _round_call(eng_on),
                                 iters=8 if smoke else 20)
    loss_off = float(_round_call(eng_off)())
    loss_on = float(_round_call(eng_on)())
    return {
        "devices": n_devices,
        "jax_device_count": jax.device_count(),
        "off_steady_ms": round(off_ms * 1e3, 3),
        "on_steady_ms": round(on_ms * 1e3, 3),
        "on_over_off": round(on_ms / off_ms, 3),
        "compile_count_on": eng_on.algo.trace_count,
        "losses_equal": loss_off == loss_on,
    }


def _forced_device_sweep(worker_flag: str, devices: list[int], smoke: bool,
                         report) -> dict:
    """Shared subprocess scaffold for the per-device-count sweeps: one
    fresh process per count (XLA_FLAGS must bind before jax
    initializes), the worker's JSON record on the last stdout line,
    stderr captured on failure.  ``report(rec)`` formats the progress
    line for one successful record."""
    out = {}
    for n in devices:
        env = dict(os.environ)
        # append so user-set XLA flags survive (last occurrence wins for
        # the device count itself)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={n}"
                            ).strip()
        cmd = [sys.executable, os.path.abspath(__file__), worker_flag,
               str(n)]
        if smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env)
        if proc.returncode != 0:
            out[str(n)] = {"error": proc.stderr[-2000:]}
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        out[str(n)] = rec
        print(report(rec))
    return out


def shard_local_sweep(devices: list[int], smoke: bool) -> dict:
    """One subprocess per device count, recording the off/on comparison."""
    return _forced_device_sweep(
        "--shard-local-worker", devices, smoke,
        lambda rec: (f"[shard-local devices={rec['devices']}] "
                     f"off={rec['off_steady_ms']}ms "
                     f"on={rec['on_steady_ms']}ms "
                     f"ratio={rec['on_over_off']} "
                     f"losses_equal={rec['losses_equal']}"))


# ------------------------------------------------------- device sweep
# The weak-scaling configuration is PINNED (independent of --smoke,
# which only shortens the timed run): cyclesfl at the client-heavy
# cut=3 split (server = the 2048->62 linear head), width 8, per-client
# batch 8, server batch 16, cohort capacity 8 — fixed GLOBAL work, so
# rounds/sec at N devices vs 1 device is directly comparable.  The
# feature pool at this cut is [cap*batch, 2048] f32; its byte geometry
# feeds the no-pool-allgather HLO assertion.
_WS_FEAT_DIM = 2048      # femnist_cnn stage-2 dense output (any width)
_WS_SB = 16


def _ws_config(n_devices: int, rounds: int) -> ExperimentConfig:
    return ExperimentConfig(
        algo="cyclesfl", task="image", rounds=rounds, n_clients=32,
        attendance=0.25, batch=8, width=8, cut=3, seed=0,
        eval_every=10**9, variable_attendance=True, collect_timing=True,
        sync_every=4, mesh_shape=(n_devices, 1),
        mesh_axes=("data", "model"),
        cycle=CycleConfig(shard_local_resample=True, server_batch=_WS_SB))


def _ws_run(cfg: ExperimentConfig, n_devices: int) -> tuple:
    """One weak-scaling measurement through the Engine's own run loop —
    the device-resident path (donated round buffers, prefetch
    double-buffer, sync_every telemetry cadence) is what's timed, not a
    harness loop — plus the compiled round's collective census and the
    pool-all-gather assertion."""
    from repro.utils import profiling
    from repro.utils.hlo_cost import assert_no_pool_allgather
    eng = Engine(cfg, donate=True, log=lambda *a, **k: None)
    res = eng.run()
    steady = res["round_time_s"]
    pool_bytes = eng.padded_capacity * cfg.batch * _WS_FEAT_DIM * 4
    sb_bytes = _WS_SB * _WS_FEAT_DIM * 4
    census = assert_no_pool_allgather(
        profiling.round_hlo(eng), pool_bytes, n_shards=n_devices,
        extra_sizes=(sb_bytes, sb_bytes // n_devices))
    rec = {
        "steady_ms": round(steady * 1e3, 3),
        "rounds_per_sec": round(1.0 / steady, 2),
        "compile_count": eng.algo.trace_count,
        "no_pool_allgather": True,
        "pool_bytes": pool_bytes,
        "collective_census": census,
    }
    return eng, rec


def sweep_worker(n_devices: int, smoke: bool) -> dict:
    """One weak-scaling point at the CURRENT process's device count:
    mesh (N, 1) over ('data', 'model'), shard-local resample, donated
    device-resident rounds, sync_every=4.  Records the plain shard-local
    round, the fused-in-shard_map variant (gather+head-loss computed
    inside the shard_map body, scalar psum across shards), a per-phase
    cost breakdown, and the collective census + no-pool-allgather
    assertion for both compiled rounds."""
    from repro.utils import profiling
    rounds = 6 if smoke else 10
    cfg = _ws_config(n_devices, rounds)
    eng, rec = _ws_run(cfg, n_devices)
    rec = {
        "devices": n_devices,
        "jax_device_count": jax.device_count(),
        "cohort_capacity": eng.cohort_capacity,
        "padded_capacity": eng.padded_capacity,
        **rec,
    }
    phases = profiling.phase_costs(eng, repeats=2 if smoke else 4)
    rec["phase_ms"] = {k: v["delta_ms"] for k, v in phases.items()}
    _, frec = _ws_run(cfg.with_cycle(fused_gather_loss=True), n_devices)
    rec["fused"] = frec
    return rec


def device_sweep(devices: list[int], smoke: bool) -> dict:
    """One subprocess per device count, then the weak-scaling verdict:
    ``weak_scaling_efficiency`` = rounds/sec at the largest count over
    rounds/sec at the smallest, at fixed global work — the tracked
    claim is that the sharded runtime at N devices is no slower than at
    1 (>= 1.0), i.e. the 1->8 slowdown is gone."""
    out = _forced_device_sweep(
        "--sweep-worker", devices, smoke,
        lambda rec: (f"[devices={rec['devices']}] "
                     f"steady_ms={rec['steady_ms']} "
                     f"rounds_per_sec={rec['rounds_per_sec']} "
                     f"fused_ms={rec['fused']['steady_ms']} "
                     f"compile_count={rec['compile_count']}"))
    recs = {int(k): v for k, v in out.items() if "error" not in v}
    if len(recs) > 1:
        lo, hi = min(recs), max(recs)
        eff = (recs[hi]["rounds_per_sec"] / recs[lo]["rounds_per_sec"])
        fused_eff = (recs[hi]["fused"]["rounds_per_sec"]
                     / recs[lo]["fused"]["rounds_per_sec"])
        out["claims"] = {
            "workload": "fixed global work (cut=3 client-heavy split)",
            "weak_scaling_efficiency": round(eff, 3),
            "weak_scaling_recovered": eff >= 1.0,
            "fused_shard_map_efficiency": round(fused_eff, 3),
            "no_pool_allgather": all(
                r.get("no_pool_allgather")
                and r.get("fused", {}).get("no_pool_allgather")
                for r in recs.values()),
            "compile_once": all(r["compile_count"] == 1
                                for r in recs.values()),
        }
        print(f"[device sweep] weak_scaling_efficiency={eff:.3f} "
              f"(devices {lo}->{hi}) fused={fused_eff:.3f} "
              f"no_pool_allgather={out['claims']['no_pool_allgather']}")
    return out


def run(smoke: bool = False) -> dict:
    if smoke:
        base = ExperimentConfig(task="image", rounds=1, n_clients=24,
                                attendance=0.25, batch=8, width=4, cut=2,
                                seed=0, eval_every=10**9)
        rounds, capacities = 8, (3, 6)
    else:
        base = ExperimentConfig(task="image", rounds=1, n_clients=60,
                                attendance=0.2, batch=16, width=8, cut=2,
                                seed=0, eval_every=10**9)
        rounds, capacities = 16, (4, 8, 16)
    result = {
        "backend": jax.default_backend(),
        "mode": "smoke" if smoke else "full",
        "config": {"n_clients": base.n_clients, "attendance": base.attendance,
                   "batch": base.batch, "width": base.width,
                   "rounds_timed": rounds},
        "algos": {},
    }
    for algo in ALGOS:
        result["algos"][algo] = bench_algo(algo, base, rounds, capacities)
        c = result["algos"][algo]["claims"]
        fx = result["algos"][algo]["fixed_size_comparison"]
        print(f"[{algo}] compile_once={c['compile_once']} "
              f"padded_ms={fx['padded_steady_ms']} "
              f"unpadded_ms={fx['unpadded_steady_ms']} "
              f"ratio={fx['padded_over_unpadded']} "
              f"unpadded_variable_compiles="
              f"{result['algos'][algo]['unpadded_variable']['compile_count']}")
    return result


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI")
    ap.add_argument("--out", default="BENCH_round_latency.json")
    ap.add_argument("--devices", default=None,
                    help="comma-separated device counts for the sharded "
                         "Engine sweep, e.g. 1,2,4,8 (one subprocess per "
                         "count)")
    ap.add_argument("--pipeline", action="store_true",
                    help="also sweep the pipelined scheduler: rounds/sec "
                         "with pipeline_depth off vs sync vs async at "
                         "each ring depth in --pipeline-depths")
    ap.add_argument("--pipeline-depths", default="0,1,2,4",
                    help="comma-separated ring depths for the pipeline "
                         "sweep (0 = scheduler off)")
    ap.add_argument("--sweep-only", action="store_true",
                    help="skip the per-algorithm base benchmark and run "
                         "only the requested sweeps (the CI scaling leg "
                         "wants just the device sweep + its claims)")
    ap.add_argument("--shard-local", nargs="?", const="1,8", default=None,
                    help="also sweep the shard-local resample off vs on "
                         "at these device counts (default 1,8; one "
                         "subprocess per count)")
    ap.add_argument("--sweep-worker", type=int, default=None,
                    help=argparse.SUPPRESS)     # internal: one sweep point
    ap.add_argument("--shard-local-worker", type=int, default=None,
                    help=argparse.SUPPRESS)     # internal: one sweep point
    args = ap.parse_args()
    if args.sweep_worker is not None:
        print(json.dumps(sweep_worker(args.sweep_worker, args.smoke)))
        return {}
    if args.shard_local_worker is not None:
        print(json.dumps(shard_local_worker(args.shard_local_worker,
                                            args.smoke)))
        return {}
    result = ({"backend": jax.default_backend(),
               "mode": "smoke" if args.smoke else "full"}
              if args.sweep_only else run(smoke=args.smoke))
    if args.pipeline:
        result["pipeline_comparison"] = pipeline_sweep(
            args.smoke,
            tuple(int(x) for x in args.pipeline_depths.split(",")))
    if args.devices:
        result["device_sweep"] = device_sweep(
            [int(x) for x in args.devices.split(",")], args.smoke)
    if args.shard_local:
        result["shard_local"] = shard_local_sweep(
            [int(x) for x in args.shard_local.split(",")], args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
