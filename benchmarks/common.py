"""Shared harness for the paper-table benchmarks.

Each ``tableN_*.py`` reproduces the *shape of result* of one paper
table on the synthetic federated tasks (offline container — DESIGN.md
§7), with the same protocol knobs: non-iid Dirichlet split, 5% (here
configurable) attendance, sample-wise test split, seeds {0..k}.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.api import Engine, ExperimentConfig
from repro.core.cyclesl import CycleConfig
from repro.core.split import make_stage_task
from repro.data.federated import FederatedDataset
from repro.data.synthetic import SyntheticImageTask
from repro.models.cnn import femnist_cnn, resnet9


@dataclass
class BenchConfig:
    algos: tuple = ("psl", "sglr", "sflv1", "sflv2",
                    "cyclepsl", "cyclesglr", "cyclesfl")
    rounds: int = 150
    n_clients: int = 100
    attendance: float = 0.15
    batch: int = 16
    lr: float = 3e-4
    alpha: float = 0.3
    width: int = 8
    cut: int = 2
    seeds: tuple = (0, 1)
    server_epochs: int = 1
    n_classes_: int = 20            # harder task; avoids per-client saturation
    style_scale: float = 0.3        # mild feature shift (paper: label skew)
    noise: float = 0.5
    samples_per_client: int = 48
    # server-side minibatch for the CycleSL inner loop (paper §3.1: the
    # standalone server task may use its own hyper-parameters; a larger
    # batch = fewer, stabler Adam steps per round — see EXPERIMENTS.md)
    server_batch: int = 64
    model: str = "femnist"          # femnist | resnet9
    n_classes: int = 20
    eval_every: int = 10
    threshold: float = 0.4          # rounds-to-accuracy threshold (Table 14)


def build(bc: BenchConfig, seed: int):
    gen = SyntheticImageTask(n_clients=bc.n_clients, alpha=bc.alpha,
                             seed=seed, n_classes=bc.n_classes,
                             img=28 if bc.model == "femnist" else 32,
                             channels=1 if bc.model == "femnist" else 3,
                             style_scale=bc.style_scale, noise=bc.noise,
                             samples_per_client=bc.samples_per_client)
    x, y, _, idx = gen.build()
    if bc.model == "femnist":
        model = femnist_cnn(n_classes=bc.n_classes, width=bc.width)
    else:
        model = resnet9(n_classes=bc.n_classes, width=bc.width)
    task = make_stage_task(model, cut=bc.cut, kind="xent")
    fed = FederatedDataset.from_arrays(x, y, idx, seed=seed)
    return task, fed


def experiment_config(bc: BenchConfig, algo_name: str, seed: int,
                      collect_timing: bool = False) -> ExperimentConfig:
    """The bench protocol as a frozen ExperimentConfig (its own per-round
    key stream, via round_key_salt, keeps historical benchmark seeds)."""
    return ExperimentConfig(
        algo=algo_name, task="image", rounds=bc.rounds,
        n_clients=bc.n_clients, attendance=bc.attendance, batch=bc.batch,
        lr_server=bc.lr, lr_client=bc.lr, alpha=bc.alpha, seed=seed,
        width=bc.width, cut=bc.cut, eval_every=bc.eval_every,
        round_key_salt=7919, collect_timing=collect_timing,
        cycle=CycleConfig(server_epochs=bc.server_epochs,
                          server_batch=bc.server_batch))


def run_algo(bc: BenchConfig, algo_name: str, seed: int,
             collect_timing: bool = False) -> dict:
    task, fed = build(bc, seed)
    cfg = experiment_config(bc, algo_name, seed, collect_timing)
    res = Engine(cfg, task=task, fed=fed, metric_key="accuracy",
                 log=lambda *a, **k: None).run()
    accs = [h["accuracy"] for h in res["history"]]
    losses = [h["test_loss"] for h in res["history"]]
    rounds_to_threshold = next(
        (h["round"] for h in res["history"] if h["accuracy"] >= bc.threshold),
        None)
    return {
        "algo": algo_name, "seed": seed,
        "final_acc": accs[-1], "best_acc": max(accs),
        "final_loss": losses[-1],
        "rounds_to_threshold": rounds_to_threshold,
        "grad_stability": res["grad_stability"],
        "round_time_s": res.get("round_time_s", 0.0),
    }


def aggregate(results: list[dict], key: str) -> tuple[float, float]:
    vals = [r[key] for r in results if r[key] is not None]
    if not vals:
        return float("nan"), float("nan")
    return float(np.mean(vals)), float(np.std(vals))
