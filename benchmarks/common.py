"""Shared harness for the paper-table benchmarks.

Each ``tableN_*.py`` reproduces the *shape of result* of one paper
table on the synthetic federated tasks (offline container — DESIGN.md
§7), with the same protocol knobs: non-iid Dirichlet split, 5% (here
configurable) attendance, sample-wise test split, seeds {0..k}.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import make_algorithm
from repro.core.cyclesl import CycleConfig
from repro.core.drift import GradStabilityTracker
from repro.core.split import make_stage_task
from repro.data.federated import FederatedDataset, sample_cohort
from repro.data.synthetic import SyntheticImageTask
from repro.launch.train import evaluate
from repro.models.cnn import femnist_cnn, resnet9
from repro.optim import adam


@dataclass
class BenchConfig:
    algos: tuple = ("psl", "sglr", "sflv1", "sflv2",
                    "cyclepsl", "cyclesglr", "cyclesfl")
    rounds: int = 150
    n_clients: int = 100
    attendance: float = 0.15
    batch: int = 16
    lr: float = 3e-4
    alpha: float = 0.3
    width: int = 8
    cut: int = 2
    seeds: tuple = (0, 1)
    server_epochs: int = 1
    n_classes_: int = 20            # harder task; avoids per-client saturation
    style_scale: float = 0.3        # mild feature shift (paper: label skew)
    noise: float = 0.5
    samples_per_client: int = 48
    # server-side minibatch for the CycleSL inner loop (paper §3.1: the
    # standalone server task may use its own hyper-parameters; a larger
    # batch = fewer, stabler Adam steps per round — see EXPERIMENTS.md)
    server_batch: int = 64
    model: str = "femnist"          # femnist | resnet9
    n_classes: int = 20
    eval_every: int = 10
    threshold: float = 0.4          # rounds-to-accuracy threshold (Table 14)


def build(bc: BenchConfig, seed: int):
    gen = SyntheticImageTask(n_clients=bc.n_clients, alpha=bc.alpha,
                             seed=seed, n_classes=bc.n_classes,
                             img=28 if bc.model == "femnist" else 32,
                             channels=1 if bc.model == "femnist" else 3,
                             style_scale=bc.style_scale, noise=bc.noise,
                             samples_per_client=bc.samples_per_client)
    x, y, _, idx = gen.build()
    if bc.model == "femnist":
        model = femnist_cnn(n_classes=bc.n_classes, width=bc.width)
    else:
        model = resnet9(n_classes=bc.n_classes, width=bc.width)
    task = make_stage_task(model, cut=bc.cut, kind="xent")
    fed = FederatedDataset.from_arrays(x, y, idx, seed=seed)
    return task, fed


def run_algo(bc: BenchConfig, algo_name: str, seed: int,
             collect_timing: bool = False) -> dict:
    task, fed = build(bc, seed)
    algo = make_algorithm(algo_name, task, adam(bc.lr), adam(bc.lr),
                          CycleConfig(server_epochs=bc.server_epochs,
                                      server_batch=bc.server_batch))
    state = algo.init(jax.random.PRNGKey(seed), fed.n_clients)
    rng = np.random.default_rng(seed + 1)
    tracker = GradStabilityTracker()
    accs, losses = [], []
    rounds_to_threshold = None
    server_time = 0.0
    for rnd in range(bc.rounds):
        cohort = sample_cohort(fed.n_clients, bc.attendance, rng, min_cohort=2)
        xs = np.stack([fed.clients[c].sample_batch(rng, bc.batch)[0]
                       for c in cohort])
        ys = np.stack([fed.clients[c].sample_batch(rng, bc.batch)[1]
                       for c in cohort])
        t0 = time.time()
        state, metrics = algo.round(state, jnp.asarray(cohort),
                                    jnp.asarray(xs), jnp.asarray(ys),
                                    jax.random.PRNGKey(seed * 7919 + rnd))
        if collect_timing:
            jax.block_until_ready(metrics["server_loss"])
            if rnd > 0:          # skip compile round
                server_time += time.time() - t0
        tracker.update(metrics)
        if (rnd + 1) % bc.eval_every == 0 or rnd == bc.rounds - 1:
            loss, mets = evaluate(task, state, fed)
            accs.append(mets["accuracy"])
            losses.append(loss)
            if rounds_to_threshold is None and mets["accuracy"] >= bc.threshold:
                rounds_to_threshold = rnd + 1
    return {
        "algo": algo_name, "seed": seed,
        "final_acc": accs[-1], "best_acc": max(accs),
        "final_loss": losses[-1],
        "rounds_to_threshold": rounds_to_threshold,
        "grad_stability": tracker.summary(),
        "round_time_s": server_time / max(1, bc.rounds - 1),
    }


def aggregate(results: list[dict], key: str) -> tuple[float, float]:
    vals = [r[key] for r in results if r[key] is not None]
    if not vals:
        return float("nan"), float("nan")
    return float(np.mean(vals)), float(np.std(vals))
