"""CycleSL core semantics: the properties that make it the paper's method."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import ALGORITHMS, make_algorithm
from repro.core.cyclesl import (CycleConfig, cyclesl_round,
                                feature_gradients, server_inner_loop)
from repro.core.feature_store import FeatureStore, gather_batch, resample_plan
from repro.core.protocol import broadcast_entity, init_entity
from repro.core.split import make_stage_task
from repro.models.cnn import femnist_cnn, mlp
from repro.optim import adam, sgd


@pytest.fixture(scope="module")
def small_task():
    return make_stage_task(mlp(8, [16], 4), cut=1, kind="xent")


def _cohort_batches(rng, C=3, b=8, d=8, classes=4):
    xs = jnp.asarray(rng.normal(size=(C, b, d)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, classes, size=(C, b)))
    return xs, ys


def test_feature_store_pool_shapes(rng):
    f = jnp.arange(2 * 3 * 5, dtype=jnp.float32).reshape(2, 3, 5)
    y = jnp.arange(6).reshape(2, 3)
    store = FeatureStore.pool(f, y)
    assert store.features.shape == (6, 5)
    assert store.size == 6
    # pooling preserves (client, sample) order row-major
    np.testing.assert_array_equal(np.asarray(store.features[3]),
                                  np.asarray(f[1, 0]))


def test_resample_plan_is_per_epoch_permutation():
    plan = resample_plan(jax.random.PRNGKey(0), total=32, epochs=3, batch=8)
    assert plan.shape == (3, 4, 8)
    for e in range(3):
        seen = np.sort(np.asarray(plan[e]).ravel())
        np.testing.assert_array_equal(seen, np.arange(32))  # no replacement
    # different epochs shuffle differently
    assert not np.array_equal(np.asarray(plan[0]), np.asarray(plan[1]))


def test_resampled_batches_are_not_client_bound(rng):
    """Paper Eq. 3: resampled server batches mix clients."""
    C, b = 4, 16
    plan = resample_plan(jax.random.PRNGKey(1), total=C * b, epochs=1, batch=b)
    owners = np.asarray(plan[0]) // b
    # every server batch should touch >1 client with overwhelming prob.
    assert all(len(np.unique(row)) > 1 for row in owners)


def test_cyclical_order_client_grads_use_updated_server(small_task, rng):
    """Eq. 5: B_i^g must be computed with θ_S^{t+1}, not θ_S^t."""
    xs, ys = _cohort_batches(rng)
    opt = sgd(0.1)
    server = init_entity(small_task.init_server(jax.random.PRNGKey(0)), opt)
    clients = broadcast_entity(
        init_entity(small_task.init_client(jax.random.PRNGKey(1)), opt), 3)
    feats = jax.vmap(small_task.client_forward)(clients.params, xs)
    store = FeatureStore.pool(feats, ys)
    server2, _ = server_inner_loop(small_task, server, opt, store,
                                   jax.random.PRNGKey(2),
                                   CycleConfig(server_epochs=1), batch=8)
    g_new = feature_gradients(small_task, server2.params, feats, ys,
                              CycleConfig())
    g_old = feature_gradients(small_task, server.params, feats, ys,
                              CycleConfig())
    # the round must produce g_new (cyclical), which differs from g_old
    _, _, metrics = cyclesl_round(small_task, server, clients, opt, opt,
                                  xs, ys, jax.random.PRNGKey(2), CycleConfig())
    got = float(metrics["feat_grad_norm_mean"])
    fg = g_new.reshape(g_new.shape[0], -1)
    want_new = float(jnp.mean(jnp.linalg.norm(fg, axis=-1)
                              / jnp.sqrt(fg.shape[-1])))
    fo = g_old.reshape(g_old.shape[0], -1)
    want_old = float(jnp.mean(jnp.linalg.norm(fo, axis=-1)
                              / jnp.sqrt(fo.shape[-1])))
    assert abs(got - want_new) < 1e-5
    assert abs(got - want_old) > 1e-7  # and it is NOT the stale-server grad


def test_server_params_frozen_during_client_phase(small_task, rng):
    """No server gradient leaks into the client phase (stop_gradient wall)."""
    xs, ys = _cohort_batches(rng)
    opt = sgd(0.1)
    server = init_entity(small_task.init_server(jax.random.PRNGKey(0)), opt)
    clients = broadcast_entity(
        init_entity(small_task.init_client(jax.random.PRNGKey(1)), opt), 3)
    ccfg = CycleConfig(server_epochs=1)
    server2, _, _ = cyclesl_round(small_task, server, clients, opt, opt,
                                  xs, ys, jax.random.PRNGKey(2), ccfg)
    # server step count advanced exactly E*steps times (inner loop only)
    assert int(server2.step) == 3  # 3 cohort batches of size 8 / batch 8


def test_cyclesglr_broadcasts_mean_gradient(small_task, rng):
    xs, ys = _cohort_batches(rng)
    opt = sgd(0.1)
    server = init_entity(small_task.init_server(jax.random.PRNGKey(0)), opt)
    feats = jax.vmap(small_task.client_forward)(
        broadcast_entity(init_entity(
            small_task.init_client(jax.random.PRNGKey(1)), opt), 3).params, xs)
    g = feature_gradients(small_task, server.params, feats, ys,
                          CycleConfig(avg_client_grads=True))
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(g[1]), atol=1e-7)


def test_server_epochs_scale_server_steps(small_task, rng):
    xs, ys = _cohort_batches(rng)
    opt = adam(1e-3)
    server = init_entity(small_task.init_server(jax.random.PRNGKey(0)), opt)
    clients = broadcast_entity(
        init_entity(small_task.init_client(jax.random.PRNGKey(1)), opt), 3)
    for E in (1, 2, 4):
        s2, _, _ = cyclesl_round(small_task, server, clients, opt, opt, xs, ys,
                                 jax.random.PRNGKey(2),
                                 CycleConfig(server_epochs=E))
        assert int(s2.step) == 3 * E


def test_all_algorithms_reduce_loss_on_learnable_task(rng):
    """Each algorithm should beat init loss on an easy separable task."""
    task = make_stage_task(mlp(8, [32], 4), cut=1, kind="xent")
    C, b = 4, 32
    w = rng.normal(size=(8, 4))
    xs_all, ys_all = [], []
    for _ in range(C):
        x = rng.normal(size=(b, 8))
        y = np.argmax(x @ w, axis=-1)
        xs_all.append(x)
        ys_all.append(y)
    xs = jnp.asarray(np.stack(xs_all), jnp.float32)
    ys = jnp.asarray(np.stack(ys_all))
    opt = adam(5e-3)
    for name in ALGORITHMS:
        algo = make_algorithm(name, task, opt, opt, CycleConfig(server_epochs=1))
        state = algo.init(jax.random.PRNGKey(0), n_clients=C)
        first = None
        for r in range(25):
            state, m = algo.round(state, jnp.arange(C), xs, ys,
                                  jax.random.PRNGKey(r))
            if first is None:
                first = float(m["server_loss"])
        last = float(m["server_loss"])
        assert last < first, f"{name}: {first} -> {last}"


def test_stage_split_e2e_equals_composition(rng):
    model = femnist_cnn(n_classes=10, width=4)
    task = make_stage_task(model, cut=2)
    params = model.init(jax.random.PRNGKey(0))
    cp, sp = params[:2], params[2:]
    x = jnp.asarray(rng.normal(size=(3, 28, 28, 1)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(task.predict(cp, sp, x)),
        np.asarray(model.apply(params, x)), atol=1e-6)
