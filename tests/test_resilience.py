"""repro.resilience: health guards, recovery policies, crash-safe
checkpoints, and the deterministic fault-injection harness.

The load-bearing guarantees:

1. **Null resilience == guard-free, bit-for-bit, every algorithm.**
   The default ``ResilienceConfig`` compiles no guard phase; stronger,
   ``guard=True`` on a fault-free run reproduces the guard-free history
   exactly (the health checks read values the round already computes)
   with the one-trace budget held.
2. **Every recovery policy completes a poisoned run** with accurate
   ``result['resilience']`` telemetry: quarantine excises persistent
   poison via the attendance mask; retry/rollback recover transient
   faults bit-for-bit (re-running a round from its pre-round state with
   the same key IS the unfaulted round).
3. **Checkpoints are crash-safe**: atomic writes, checksum-verified
   restore that falls back past torn step dirs, gc that never deletes
   the last valid step — proven end-to-end by a subprocess SIGKILL'd
   mid-run whose resumed history is bit-for-bit the uninterrupted one.
"""
import argparse
import json
import os
import signal
import subprocess
import sys
import time
from dataclasses import replace

import numpy as np
import pytest

from repro.api import PROGRAMS, Engine, ExperimentConfig
from repro.checkpoint import (checkpoint_valid, latest_step, load_checkpoint,
                              save_checkpoint, valid_steps)
from repro.core.split import make_stage_task
from repro.data.federated import FederatedDataset
from repro.models.cnn import mlp
from repro.resilience import (ACTIONS, FaultConfig, FaultInjectedError,
                              FaultStream, RecoveryController,
                              ResilienceConfig, build_fault_stream,
                              quarantine_mask)

pytestmark = pytest.mark.resilience

N, ROUNDS = 24, 4


def _fed(n=N, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n * 12, 8)).astype(np.float32)
    w = rng.normal(size=(8, 4))
    y = np.argmax(x @ w, axis=-1)
    idx = np.arange(len(x)).reshape(n, -1)
    return FederatedDataset.from_arrays(x, y, list(idx), seed=seed)


@pytest.fixture(scope="module")
def setup():
    return make_stage_task(mlp(8, [8], 4), cut=1, kind="xent"), _fed()


def _cfg(**kw):
    base = dict(algo="cyclesfl", rounds=ROUNDS, n_clients=N, attendance=0.25,
                min_cohort=2, batch=4, width=8, cut=1, seed=0,
                eval_every=ROUNDS)
    base.update(kw)
    return ExperimentConfig(**base)


def _run(cfg, task, fed):
    eng = Engine(cfg, task=task, fed=fed, metric_key="accuracy",
                 log=lambda *a, **k: None)
    res = eng.run()
    res["history"] = [{k: v for k, v in row.items() if k != "elapsed_s"}
                      for row in res["history"]]
    return eng, res


GUARD = ResilienceConfig(guard=True)


# ---------------------------------------------- null/guard-clean golden
@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_guard_clean_bit_for_bit(name, setup):
    """The null config IS the default (same object, same trace); the
    stronger claim: arming the guard on a fault-free run changes no
    history bit for any registered algorithm, and both compile once."""
    task, fed = setup
    base = _cfg(algo=name)
    e0, r0 = _run(base, task, fed)
    e1, r1 = _run(replace(base, resilience=GUARD), task, fed)
    assert r0["history"] == r1["history"], name
    assert "resilience" not in r0
    assert r1["resilience"]["faulted_rounds"] == 0
    assert e0.algo.trace_count == 1
    assert e1.algo.trace_count == 1


def test_null_config_builds_nothing(setup):
    task, fed = setup
    eng = Engine(_cfg(), task=task, fed=fed, log=lambda *a: None)
    assert eng.faults is None and eng.recovery is None
    assert not ExperimentConfig().resilience.active
    assert build_fault_stream(FaultConfig(), 0) is None


# --------------------------------------------------- recovery policies
def test_quarantine_excises_persistent_poison(setup):
    """A client slot delivering NaN on EVERY attempt can only be saved
    by quarantine: the blamed slot is masked out mid-round, the client
    banned from future cohorts, and the run completes finite."""
    task, fed = setup
    cfg = _cfg(rounds=6, eval_every=3, resilience=ResilienceConfig(
        guard=True, on_nonfinite="quarantine",
        faults=FaultConfig(nan_rate=0.4, persist=10)))
    eng, res = _run(cfg, task, fed)
    tel = res["resilience"]
    assert tel["faulted_rounds"] > 0
    assert tel["quarantine_events"] > 0
    assert tel["quarantined_clients"]
    assert tel["faults"]["nonfinite"] == tel["faulted_rounds"]
    assert all(np.isfinite(row["test_loss"]) for row in res["history"])
    # per-round rows name the action taken and the ledger size
    for row in tel["per_round"]:
        assert row["attempts"] >= 1
        assert set(row["actions"]) <= set(ACTIONS)
        assert row["quarantined_slots"] >= 1
    # the ban sticks: the controller zeroes quarantined clients out of
    # every future cohort draw (the sampler consumes these weights)
    banned = tel["quarantined_clients"]
    w = eng.recovery.sampling_weights(None)
    assert w is not None
    assert all(w[c] == 0.0 for c in banned)
    assert (w > 0).sum() == N - len(banned)


@pytest.mark.parametrize("action", ["retry", "rollback"])
def test_transient_fault_recovers_bit_for_bit(action, setup):
    """A transient NaN (clears on the next attempt) recovered by retry
    OR rollback-to-previous-round re-runs the round from its pre-round
    state with the same key — so the final history is bit-for-bit the
    fault-free guarded run's."""
    task, fed = setup
    clean = _cfg(resilience=GUARD)
    _, r_clean = _run(clean, task, fed)
    cfg = replace(clean, resilience=ResilienceConfig(
        guard=True, on_nonfinite=action,
        faults=FaultConfig(nan_rate=0.5, persist=0)))
    _, r = _run(cfg, task, fed)
    tel = r["resilience"]
    assert tel["faulted_rounds"] > 0
    if action == "retry":
        assert tel["retries"] > 0
    else:
        # round 0 has an empty ring -> escalates to retry; later rounds
        # roll back to the newest snapshot (== the pre-round state)
        assert tel["rollbacks"] + tel["retries"] == tel["faulted_rounds"]
    assert r["history"] == r_clean["history"], action


def test_dispatch_error_retries_bit_for_bit(setup):
    """An injected dispatch exception (guard OFF — the controller alone
    handles it) retries on a fresh draw and reproduces the unfaulted
    history exactly."""
    task, fed = setup
    _, r0 = _run(_cfg(), task, fed)
    cfg = _cfg(resilience=ResilienceConfig(
        faults=FaultConfig(error_rate=0.4)))
    eng, r = _run(cfg, task, fed)
    assert r["resilience"]["faults"]["error"] > 0
    assert r["history"] == r0["history"]
    assert eng.algo.trace_count == 1


def test_unguarded_engine_dies_on_injected_error(setup):
    """max_retries=0 exhausts immediately — the fault surfaces instead
    of being silently swallowed."""
    from repro.resilience import ResilienceExhaustedError
    task, fed = setup
    cfg = _cfg(resilience=ResilienceConfig(
        max_retries=0, faults=FaultConfig(error_rate=0.999)))
    with pytest.raises(ResilienceExhaustedError):
        _run(cfg, task, fed)


def test_spike_detector_flags_via_policy(setup):
    """An EMA loss-spike triggers the on_spike action once warm; with
    'ignore' the run records it and keeps the round."""
    task, fed = setup
    cfg = _cfg(rounds=6, eval_every=6, resilience=ResilienceConfig(
        guard=True, on_spike="ignore", spike_factor=1.0001,
        spike_warmup=2, ema_alpha=1.0))
    # spike_factor ~1 + alpha 1.0: any loss increase over the previous
    # round reads as a spike once the warmup passes
    _, r = _run(cfg, task, fed)
    tel = r["resilience"]
    assert tel["faults"]["spike"] == tel["faulted_rounds"]


# ----------------------------------------------------- pipelined rounds
@pytest.mark.parametrize("staleness", ["sync", "async"])
def test_pipelined_recovery_completes(staleness, setup):
    task, fed = setup
    base = _cfg(pipeline_depth=1, pipeline_staleness=staleness,
                resilience=GUARD)
    eng0, r0 = _run(base, task, fed)
    cfg = replace(base, resilience=ResilienceConfig(
        guard=True, on_nonfinite="quarantine",
        faults=FaultConfig(nan_rate=0.4, persist=10)))
    eng, r = _run(cfg, task, fed)
    assert r["resilience"]["quarantine_events"] > 0
    assert all(np.isfinite(row["test_loss"]) for row in r["history"])
    for e in (eng0, eng):
        assert e.pipeline.extract_traces == 1
        assert e.pipeline.tail_traces == 1
    if staleness == "sync":
        # guarded fault-free pipelined == guarded sequential
        _, r_seq = _run(replace(base, pipeline_depth=0), task, fed)
        assert r0["history"] == r_seq["history"]


# --------------------------------------------------- fault determinism
def test_fault_stream_replays_exactly():
    cfg = FaultConfig(nan_rate=0.5, nan_slots=2, error_rate=0.3,
                      ckpt_rate=0.4, persist=1)
    a, b = FaultStream(cfg, 7), FaultStream(cfg, 7)
    for rnd in list(range(20)) + list(range(20))[::-1]:
        for att in (0, 1, 2):
            np.testing.assert_array_equal(a.nan_slots_for(rnd, att, 6),
                                          b.nan_slots_for(rnd, att, 6))
            ra = rb = None
            try:
                a.check_dispatch(rnd, att)
            except FaultInjectedError as e:
                ra = (e.rnd, e.attempt)
            try:
                b.check_dispatch(rnd, att)
            except FaultInjectedError as e:
                rb = (e.rnd, e.attempt)
            assert ra == rb
        assert a.ckpt_corrupt(rnd) == b.ckpt_corrupt(rnd)
    # persistence gate: past `persist` attempts the delivery is clean
    fired = [r for r in range(50) if a.nan_slots_for(r, 0, 6).size]
    assert fired, "expected some poisoned rounds at rate 0.5"
    assert all(a.nan_slots_for(r, 2, 6).size == 0 for r in fired)


def test_fault_spec_round_trips():
    cfg = FaultConfig.from_spec("nan=0.2,error=0.1,ckpt=0.5,slots=2,persist=3")
    assert cfg == FaultConfig(nan_rate=0.2, error_rate=0.1, ckpt_rate=0.5,
                              nan_slots=2, persist=3)
    assert FaultConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(KeyError):
        FaultConfig.from_spec("bogus=1")
    with pytest.raises(ValueError):
        FaultConfig(nan_rate=1.5).validate()


# ------------------------------------------------------ config plumbing
def test_resilience_config_round_trips():
    rc = ResilienceConfig(guard=True, on_nonfinite="rollback",
                          max_retries=5, ring_size=3,
                          faults=FaultConfig(nan_rate=0.1))
    assert ResilienceConfig.from_dict(rc.to_dict()) == rc
    cfg = ExperimentConfig(resilience=rc)
    rt = ExperimentConfig.from_dict(cfg.to_dict())
    assert rt == cfg
    # pre-resilience JSONs simply lack the key -> null config
    d = cfg.to_dict()
    d.pop("resilience")
    assert ExperimentConfig.from_dict(d).resilience == ResilienceConfig()


def test_resilience_flags_round_trip():
    ap = argparse.ArgumentParser()
    ExperimentConfig.add_arguments(ap)
    args = ap.parse_args(["--guard", "--on-nonfinite", "rollback",
                          "--max-retries", "5", "--snapshot-ring", "4",
                          "--faults", "nan=0.2,persist=1"])
    cfg = ExperimentConfig.from_flags(args)
    rc = cfg.resilience
    assert rc.guard and rc.on_nonfinite == "rollback"
    assert rc.max_retries == 5 and rc.ring_size == 4
    assert rc.faults.nan_rate == 0.2 and rc.faults.persist == 1


def test_validation_rejects_bad_policies():
    with pytest.raises(ValueError):
        ResilienceConfig(on_nonfinite="explode").validate()
    with pytest.raises(ValueError):
        ResilienceConfig(ring_size=0).validate()
    with pytest.raises(ValueError):
        ExperimentConfig(pad_cohorts=False, resilience=ResilienceConfig(
            guard=True, on_nonfinite="quarantine")).validate()
    # quarantine-free policies don't need padded cohorts
    ExperimentConfig(pad_cohorts=False, resilience=ResilienceConfig(
        guard=True, on_nonfinite="retry", on_error="retry")).validate()


# ------------------------------------------------- crash-safe ckpt I/O
def _tree(v=0.0):
    return {"w": np.full((4, 3), v, np.float32),
            "b": {"x": np.arange(6).astype(np.int32)}}


def test_checkpoint_checksum_detects_truncation(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1.0))
    save_checkpoint(d, 2, _tree(2.0))
    assert latest_step(d) == 2
    # tear step 2's payload: a partial write frozen mid-flight
    FaultStream.corrupt_checkpoint(d, 2)
    assert not checkpoint_valid(os.path.join(d, "step_2"))
    with pytest.warns(RuntimeWarning, match="corrupt"):
        assert latest_step(d) == 1
    with pytest.warns(RuntimeWarning):
        tree, step = load_checkpoint(d, _tree())
    assert step == 1
    np.testing.assert_array_equal(tree["w"], _tree(1.0)["w"])


def test_gc_never_deletes_last_valid_step(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3):
        save_checkpoint(d, s, _tree(float(s)), keep=1)
    assert valid_steps(d) == [3]
    # corrupt the only survivor, then write a fresh step with keep=1:
    # gc must keep the newest VALID step and may reclaim the torn one
    FaultStream.corrupt_checkpoint(d, 3)
    save_checkpoint(d, 4, _tree(4.0), keep=1)
    assert valid_steps(d) == [4]
    FaultStream.corrupt_checkpoint(d, 4)
    # nothing valid newer: loading falls through with a clear error
    with pytest.warns(RuntimeWarning):
        assert latest_step(d) is None
    with pytest.warns(RuntimeWarning):
        with pytest.raises(FileNotFoundError):
            load_checkpoint(d, _tree())


def test_checkpoint_atomic_write_leaves_no_tmp(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 7, _tree(7.0))
    entries = os.listdir(d)
    assert entries == ["step_7"]
    manifest = json.load(open(os.path.join(d, "step_7", "manifest.json")))
    assert manifest["format"] == 2
    assert "arrays.npz" in manifest["checksum"]


def test_legacy_checkpoint_without_checksum_still_loads(tmp_path):
    """Format-1 dirs (no checksum) validate via np.load and keep working."""
    d = str(tmp_path)
    save_checkpoint(d, 3, _tree(3.0))
    mpath = os.path.join(d, "step_3", "manifest.json")
    m = json.load(open(mpath))
    del m["checksum"], m["format"]
    json.dump(m, open(mpath, "w"))
    assert checkpoint_valid(os.path.join(d, "step_3"))
    assert latest_step(d) == 3


# -------------------------------------------- SIGKILL crash/resume e2e
def _harness_args(ckpt_dir, rounds=6, **kw):
    ns = argparse.Namespace(
        ckpt_dir=ckpt_dir, rounds=rounds, algo="cyclesfl", clients=N,
        attendance=0.25, batch=4, seed=0, resume=False, guard=False,
        faults="", pipeline_depth=0, pipeline_staleness="sync",
        sleep_per_round=0.0, out=None)
    for k, v in kw.items():
        setattr(ns, k, v)
    return ns


def _strip(rows):
    return [{k: v for k, v in r.items() if k != "elapsed_s"} for r in rows]


QUARANTINE_FAULTS = ResilienceConfig(
    guard=True, on_nonfinite="quarantine",
    faults=FaultConfig(nan_rate=0.6, persist=10))


@pytest.mark.parametrize("pipeline", [0, 1, 2])
def test_quarantine_ledger_survives_resume(pipeline, setup, tmp_path):
    """Resume must be behavior-identical UNDER RECOVERY: the quarantine
    ledger, its per-round event history, and the spike-EMA carry are
    persisted in checkpoint metadata, so a resumed run keeps its bans
    (and replays the original's weighted cohort draws exactly) instead
    of silently re-admitting poisoned clients."""
    task, fed = setup
    base = dict(rounds=6, eval_every=3, pipeline_depth=pipeline,
                resilience=QUARANTINE_FAULTS)
    _, golden = _run(_cfg(ckpt_dir=str(tmp_path / "g"), **base), task, fed)
    assert golden["resilience"]["quarantined_clients"], \
        "fixture must actually quarantine someone"
    # partial run to round 3, then a FRESH engine resumes to 6
    ck = str(tmp_path / "p")
    _run(_cfg(ckpt_dir=ck, **{**base, "rounds": 3}), task, fed)
    eng, resumed = _run(_cfg(ckpt_dir=ck, resume=True, **base), task, fed)
    assert resumed["resumed_from_round"] == 3
    # the restored ledger + history-aware sampling replay reproduce the
    # uninterrupted run bit-for-bit
    want = {r["round"]: r for r in golden["history"]}
    for row in resumed["history"]:
        assert row == want[row["round"]], row["round"]
    assert resumed["resilience"]["quarantined_clients"] == \
        golden["resilience"]["quarantined_clients"]
    assert resumed["resilience"]["quarantine_events"] == \
        golden["resilience"]["quarantine_events"]
    # the event history itself round-trips through export/restore
    state = eng.recovery.export_state()
    fresh = RecoveryController(QUARANTINE_FAULTS, N, log=lambda *a: None)
    fresh.restore_state(state)
    assert fresh.quarantined == eng.recovery.quarantined
    assert fresh.quarantine_history == eng.recovery.quarantine_history
    assert fresh.export_state() == state


@pytest.mark.parametrize("depth", [1, 2])
def test_async_depth_ledger_survives_resume(depth, setup, tmp_path):
    """The depth-L generalization of the ledger golden for ASYNC
    schedules.  An async resume re-primes the ring with fresh extracts,
    so the resumed history is not bit-for-bit by design — but the fault
    stream is state-independent (deterministic per (round, attempt)),
    so the quarantine decisions, their event history, and the bounded
    lag must match the uninterrupted run exactly.  The resumed engine's
    draw-time ledger offset must equal the ring depth: round r's cohort
    was drawn L rounds early in the golden run, against the bans known
    at r - L."""
    task, fed = setup
    base = dict(rounds=6, eval_every=3, pipeline_depth=depth,
                pipeline_staleness="async", resilience=QUARANTINE_FAULTS)
    _, golden = _run(_cfg(ckpt_dir=str(tmp_path / "g"), **base), task, fed)
    assert golden["resilience"]["quarantined_clients"], \
        "fixture must actually quarantine someone"
    assert golden["pipeline"]["max_theta_s_lag_rounds"] <= depth
    ck = str(tmp_path / "p")
    _run(_cfg(ckpt_dir=ck, **{**base, "rounds": 3}), task, fed)
    eng, resumed = _run(_cfg(ckpt_dir=ck, resume=True, **base), task, fed)
    assert resumed["resumed_from_round"] == 3
    assert eng._ledger_offset == depth
    assert resumed["resilience"]["quarantined_clients"] == \
        golden["resilience"]["quarantined_clients"]
    assert resumed["resilience"]["quarantine_events"] == \
        golden["resilience"]["quarantine_events"]
    assert resumed["pipeline"]["max_theta_s_lag_rounds"] <= depth


def test_sigkill_deep_sync_resume_bit_for_bit(tmp_path):
    """SIGKILL-resume through the depth-2 sync ring: the subprocess
    harness runs the pipelined schedule, dies mid-flight, and the
    resumed history must still match the uninterrupted pipelined run
    row-for-row (sync at any depth is bit-for-bit sequential, and the
    checkpoint protocol is oblivious to the ring)."""
    from repro.resilience import harness
    ck = str(tmp_path / "ck")
    golden = harness.build_engine(
        _harness_args(str(tmp_path / "golden"), pipeline_depth=2)).run()
    env = dict(os.environ, PYTHONPATH="src")
    cwd = os.path.dirname(os.path.dirname(__file__))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.resilience.harness",
         "--ckpt-dir", ck, "--rounds", "6", "--clients", str(N),
         "--batch", "4", "--pipeline-depth", "2",
         "--sleep-per-round", "0.5"],
        env=env, cwd=cwd,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    try:
        deadline = time.time() + 180
        while time.time() < deadline:
            if latest_step(ck) is not None and latest_step(ck) >= 2:
                break
            if proc.poll() is not None:
                pytest.fail("harness exited before checkpointing")
            time.sleep(0.05)
        else:
            pytest.fail("harness never wrote step_2")
        os.kill(proc.pid, signal.SIGKILL)
    finally:
        proc.wait()
    killed_at = latest_step(ck)
    assert killed_at is not None and killed_at < 6
    out = str(tmp_path / "resumed.json")
    subprocess.run(
        [sys.executable, "-m", "repro.resilience.harness",
         "--ckpt-dir", ck, "--rounds", "6", "--clients", str(N),
         "--batch", "4", "--pipeline-depth", "2",
         "--resume", "--out", out],
        env=env, cwd=cwd, check=True,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL, timeout=300)
    resumed = json.load(open(out))
    assert resumed["resumed_from_round"] == killed_at
    want = {r["round"]: r for r in _strip(golden["history"])}
    got = _strip(resumed["history"])
    assert got, "resumed run produced no history"
    for row in got:
        assert row == want[row["round"]], row["round"]


def test_resume_without_ledger_metadata_keeps_fresh_controller(
        setup, tmp_path):
    """Older checkpoints (no 'resilience' metadata) resume with a clean
    controller instead of crashing — forward-compat only, by design."""
    task, fed = setup
    ck = str(tmp_path / "ck")
    _run(_cfg(rounds=3, eval_every=3, ckpt_dir=ck), task, fed)  # no guard
    cfg = _cfg(rounds=6, eval_every=3, ckpt_dir=ck, resume=True,
               resilience=QUARANTINE_FAULTS)
    eng, res = _run(cfg, task, fed)
    assert res["resumed_from_round"] == 3
    assert all(np.isfinite(r["test_loss"]) for r in res["history"])


def test_sigkill_resume_keeps_bans(tmp_path):
    """The subprocess variant of the ledger golden: SIGKILL a guarded
    run with persistent NaN clients mid-flight, resume, and prove the
    bans and history tail survive the crash bit-for-bit."""
    from repro.resilience import harness
    spec = "nan=0.6,persist=10"
    golden = harness.build_engine(
        _harness_args(str(tmp_path / "golden"), guard=True,
                      faults=spec)).run()
    assert golden["resilience"]["quarantined_clients"]
    ck = str(tmp_path / "ck")
    env = dict(os.environ, PYTHONPATH="src")
    cwd = os.path.dirname(os.path.dirname(__file__))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.resilience.harness",
         "--ckpt-dir", ck, "--rounds", "6", "--clients", str(N),
         "--batch", "4", "--guard", "--faults", spec,
         "--sleep-per-round", "0.5"],
        env=env, cwd=cwd,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    try:
        deadline = time.time() + 180
        while time.time() < deadline:
            if latest_step(ck) is not None and latest_step(ck) >= 2:
                break
            if proc.poll() is not None:
                pytest.fail("harness exited before checkpointing")
            time.sleep(0.05)
        else:
            pytest.fail("harness never wrote step_2")
        os.kill(proc.pid, signal.SIGKILL)
    finally:
        proc.wait()
    killed_at = latest_step(ck)
    assert killed_at is not None and killed_at < 6
    out = str(tmp_path / "resumed.json")
    subprocess.run(
        [sys.executable, "-m", "repro.resilience.harness",
         "--ckpt-dir", ck, "--rounds", "6", "--clients", str(N),
         "--batch", "4", "--guard", "--faults", spec,
         "--resume", "--out", out],
        env=env, cwd=cwd, check=True,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL, timeout=300)
    resumed = json.load(open(out))
    assert resumed["resumed_from_round"] == killed_at
    want = {r["round"]: r for r in _strip(golden["history"])}
    for row in _strip(resumed["history"]):
        assert row == want[row["round"]], row["round"]
    assert resumed["resilience"]["quarantined_clients"] == \
        golden["resilience"]["quarantined_clients"]


def test_sigkill_mid_round_resume_bit_for_bit(tmp_path):
    """Kill a run with SIGKILL mid-round, resume from its crash-safe
    checkpoints, and match the uninterrupted run's history exactly."""
    from repro.resilience import harness
    ck = str(tmp_path / "ck")
    golden = harness.build_engine(_harness_args(str(tmp_path / "golden"),
                                                sleep_per_round=0.0)).run()
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.resilience.harness",
         "--ckpt-dir", ck, "--rounds", "6", "--clients", str(N),
         "--batch", "4", "--sleep-per-round", "0.5"],
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    try:
        deadline = time.time() + 180
        while time.time() < deadline:
            if latest_step(ck) is not None and latest_step(ck) >= 2:
                break
            if proc.poll() is not None:
                pytest.fail("harness exited before checkpointing")
            time.sleep(0.05)
        else:
            pytest.fail("harness never wrote step_2")
        os.kill(proc.pid, signal.SIGKILL)
    finally:
        proc.wait()
    killed_at = latest_step(ck)
    assert killed_at is not None and killed_at < 6
    out = str(tmp_path / "resumed.json")
    subprocess.run(
        [sys.executable, "-m", "repro.resilience.harness",
         "--ckpt-dir", ck, "--rounds", "6", "--clients", str(N),
         "--batch", "4", "--resume", "--out", out],
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        check=True, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        timeout=300)
    resumed = json.load(open(out))
    assert resumed["resumed_from_round"] == killed_at
    want = {r["round"]: r for r in _strip(golden["history"])}
    got = _strip(resumed["history"])
    assert got, "resumed run produced no history"
    for row in got:
        assert row == want[row["round"]], row["round"]
