"""Weak-scaling runtime contracts (ISSUE 8): device-resident rounds,
the sync_every telemetry cadence, donation goldens, shard-aligned
cohort padding, the HLO collective census, and the fused gather+loss
computed inside the shard_map body.

The tentpole contract: none of the latency work moves a value.  The
donated, prefetched, sync_every>1 round stream is bit-for-bit the
classic per-round-synced stream at the same donation setting; the
shard-aligned capacity round-up never changes which clients are drawn;
the fused shard-local loss equals the unsharded fused kernel path.  The
forced multi-device cases run in a subprocess because the host device
count binds at jax initialization.
"""
import json
import os
import subprocess
import sys
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Engine, ExperimentConfig
from repro.api.registry import PROGRAMS
from repro.core.feature_store import FeatureStore, shard_local_fused_loss
from repro.kernels import ops
from repro.utils.hlo_cost import assert_no_pool_allgather, collective_census
from repro.utils.profiling import RoundProfiler, phase_costs, round_hlo

TINY = dict(task="image", rounds=3, n_clients=8, attendance=0.5, batch=4,
            width=4, eval_every=3, seed=0)


class _Rec:
    def __init__(self):
        self.state = None

    def on_round(self, engine, rnd, state, metrics):
        self.state = state


def _run(cfg, donate):
    rec = _Rec()
    eng = Engine(cfg, donate=donate, callbacks=(rec,),
                 log=lambda *a, **k: None)
    res = eng.run()
    return eng, res, rec.state


def _assert_states_equal(a, b, msg):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


# ------------------------------------------------- sync_every cadence
def test_sync_every_is_value_exact_and_adds_no_traces():
    """The telemetry cadence is pure host-side bookkeeping: any
    sync_every produces bit-identical state and eval history, and the
    round still traces exactly once (the cadence lives outside the
    jitted dispatch)."""
    base = ExperimentConfig(algo="cyclesfl", collect_timing=True,
                            mesh_shape=(1, 1), **TINY)
    runs = {}
    for k in (1, 2, 5):
        eng, res, state = _run(replace(base, sync_every=k), donate=False)
        assert eng.algo.trace_count == 1, f"sync_every={k} retraced"
        runs[k] = (res, state)
    ref_res, ref_state = runs[1]
    for k in (2, 5):
        res, state = runs[k]
        _assert_states_equal(ref_state, state, f"sync_every={k} state")
        assert [h["test_loss"] for h in res["history"]] == \
            [h["test_loss"] for h in ref_res["history"]], k


def test_sync_every_validation_and_flag():
    with pytest.raises(ValueError, match="sync_every"):
        ExperimentConfig(sync_every=0).validate()
    # resilience guard needs per-round health verdicts: the engine must
    # fall back to per-round syncs, not skip guard windows
    cfg = ExperimentConfig(algo="cyclesfl", sync_every=4, **TINY)
    cfg = replace(cfg, resilience=replace(cfg.resilience, guard=True))
    cfg.validate()                       # cadence + guard may coexist


# --------------------------------------- donation + device residency
@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_donated_mesh_round_matches_unsharded(name):
    """The scaling path's golden, per registered algorithm: donated
    buffers + the prefetched device-resident input stream + sync_every>1
    on a 1-device mesh reproduce the donated unsharded Engine exactly.
    (Donation itself is compared at the SAME setting on both sides — it
    changes XLA fusion choices at ~1 ulp, which is why it stays opt-in
    on CPU.)  The 8-device version runs in the subprocess golden."""
    base = ExperimentConfig(algo=name, collect_timing=True, **TINY)
    _, ref_res, ref_state = _run(base, donate=True)
    eng, res, state = _run(
        replace(base, mesh_shape=(1, 1), sync_every=2), donate=True)
    assert eng.algo.trace_count == 1
    _assert_states_equal(ref_state, state, f"{name}: donated mesh state")
    assert [h["test_loss"] for h in res["history"]] == \
        [h["test_loss"] for h in ref_res["history"]], name


# ------------------------------------------------ shard-aligned padding
def test_padded_capacity_identity_off_mesh_and_at_one_device():
    """shard_aligned_capacity is identity when there is nothing to
    align: no mesh, or a single batch shard."""
    from repro.sharding.specs import shard_aligned_capacity
    assert shard_aligned_capacity(None, 6) == 6
    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    assert shard_aligned_capacity(mesh1, 6) == 6
    eng = Engine(ExperimentConfig(algo="cyclesfl", mesh_shape=(1, 1),
                                  **TINY), donate=False,
                 log=lambda *a, **k: None)
    assert eng.padded_capacity == eng.cohort_capacity


# ---------------------------------------------------- collective census
_SYNTH_HLO = """\
HloModule synth

ENTRY %main (p0: f32[8,2048], p1: f32[98,2048]) -> f32[64,2048] {
  %p0 = f32[8,2048]{1,0} parameter(0)
  %p1 = f32[98,2048]{1,0} parameter(1)
  %wg = f32[784,2048]{1,0} all-gather(f32[98,2048]{1,0} %p1), dimensions={0}
  ROOT %ag = f32[64,2048]{1,0} all-gather(f32[8,2048]{1,0} %p0), dimensions={0}
}
"""


def test_collective_census_records_distinct_op_sizes():
    cen = collective_census(_SYNTH_HLO)
    ag = cen["all-gather"]
    assert ag["sites"] == 2
    # operand sizes: the 8x2048 pool shard (65536 B) and the 98x2048
    # weight shard (802816 B) — both distinct entries
    assert ag["op_bytes"] == [8 * 2048 * 4, 98 * 2048 * 4]
    assert ag["max_op_bytes"] == 98 * 2048 * 4


def test_assert_no_pool_allgather_is_size_targeted():
    """The assertion trips on a pool-shaped all-gather operand (one
    batch-axis shard of D_S^f) and ONLY on that: an FSDP weight
    rehydration gather that happens to be larger must pass."""
    pool_bytes = 64 * 2048 * 4
    with pytest.raises(AssertionError, match="pool-sized"):
        assert_no_pool_allgather(_SYNTH_HLO, pool_bytes, n_shards=8)
    # same module, pool geometry that matches nothing -> passes even
    # though a BIGGER (weight) all-gather is present
    cen = assert_no_pool_allgather(_SYNTH_HLO, 48 * 1000 * 4, n_shards=8)
    assert "all-gather" in cen


# ------------------------------------------- fused loss inside shard_map
def test_shard_local_fused_loss_matches_unsharded_fused_kernel():
    """Loss and head-weight gradient of the shard_map-interior fused
    gather+loss equal the unsharded fused path (the masked per-shard
    partials partition the minibatch, so only summation order differs).
    Runs the widest mesh this process has; the forced 8-shard case is
    covered by the subprocess golden."""
    n = 8 if jax.device_count() >= 8 else 1
    mesh = jax.make_mesh((n, 1), ("data", "model"),
                         devices=jax.devices()[:n])
    rng = np.random.default_rng(5)
    feats = jnp.asarray(rng.normal(size=(48, 24)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 10, size=(48,)), jnp.int32)
    idx = jnp.asarray(rng.integers(0, 48, size=(16,)), jnp.int32)
    w = jnp.asarray(rng.normal(size=(24, 10)) * 0.1, jnp.float32)
    store = FeatureStore(feats, labels)
    ref_l, ref_dw = jax.value_and_grad(
        lambda w: ops.fused_gather_loss_mean(feats, labels, idx, w))(w)
    sl_l, sl_dw = jax.jit(jax.value_and_grad(
        lambda w: shard_local_fused_loss(store, idx, w, mesh)))(w)
    np.testing.assert_allclose(float(sl_l), float(ref_l), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sl_dw), np.asarray(ref_dw),
                               atol=1e-6)


def test_fused_shard_local_round_traces_once_and_trains():
    """cyclesfl with BOTH shard_local_resample and fused_gather_loss on
    a mesh (previously mutually exclusive) compiles once and produces
    finite losses at a cut that exposes the linear server head."""
    cfg = ExperimentConfig(algo="cyclesfl", mesh_shape=(1, 1), cut=3,
                           **TINY)
    cfg = cfg.with_cycle(shard_local_resample=True, fused_gather_loss=True)
    eng, res, _ = _run(cfg, donate=False)
    assert eng.algo.trace_count == 1
    assert np.isfinite(res["history"][-1]["test_loss"])


# -------------------------------------------------- profiler + phases
def test_profiler_sections_and_phase_costs():
    """The opt-in RoundProfiler shows up in the run result with the
    host-side sections populated, and the per-phase prefix timer covers
    every phase of the program."""
    prof = RoundProfiler()
    cfg = ExperimentConfig(algo="cyclesfl", collect_timing=True,
                           mesh_shape=(1, 1), sync_every=2, **TINY)
    eng = Engine(cfg, donate=False, profiler=prof,
                 log=lambda *a, **k: None)
    res = eng.run()
    assert set(res["profile"]) >= {"sample", "dispatch", "eval"}
    assert res["profile"]["dispatch"]["calls"] == cfg.rounds
    costs = phase_costs(eng, repeats=1)
    assert set(costs) == {"ExtractFeatures", "ServerUpdate",
                          "FeatureGradients", "ClientUpdate", "Commit"}
    assert "HloModule" in round_hlo(eng)


# ------------------------------------------------- forced 8-device golden
_SUBPROC = r"""
import json
from dataclasses import replace
import jax, numpy as np
from repro.api import Engine, ExperimentConfig
from repro.api.registry import PROGRAMS
import jax.numpy as jnp
from repro.core.feature_store import FeatureStore, shard_local_fused_loss
from repro.kernels import ops

quiet = lambda *a, **k: None
rep = {"devices": jax.device_count(), "algos": {}}
base = ExperimentConfig(task="image", rounds=2, n_clients=8, attendance=0.5,
                        batch=4, width=4, eval_every=2, seed=0)
for name in sorted(PROGRAMS):
    ref = Engine(replace(base, algo=name), donate=True, log=quiet).run()
    eng = Engine(replace(base, algo=name, mesh_shape=(8, 1),
                         mesh_axes=("data", "model"), sync_every=2,
                         collect_timing=True), donate=True, log=quiet)
    res = eng.run()
    rep["algos"][name] = {
        "diff": abs(res["history"][-1]["test_loss"]
                    - ref["history"][-1]["test_loss"]),
        "traces": eng.algo.trace_count,
    }

# shard-aligned padding: capacity 6 does not divide 8 shards
pcfg = replace(base, algo="cyclesfl", n_clients=12, attendance=0.5)
eng_u = Engine(pcfg, donate=False, log=quiet)
eng_m = Engine(replace(pcfg, mesh_shape=(8, 1),
                       mesh_axes=("data", "model")), donate=False, log=quiet)
ids_u = np.asarray(eng_u.sample_round(np.random.default_rng(3))[0])
cm, xm, ym, mask = eng_m.sample_round(np.random.default_rng(3))
rep["padding"] = {
    "cohort_capacity": eng_m.cohort_capacity,
    "padded_capacity": eng_m.padded_capacity,
    "live_prefix_equal": bool(
        (np.asarray(cm)[: eng_u.cohort_capacity] == ids_u).all()),
    "mask_live": float(np.asarray(mask).sum()),
}

# fused loss inside shard_map at 8 real shards
rng = np.random.default_rng(5)
feats = jnp.asarray(rng.normal(size=(48, 24)), jnp.float32)
labels = jnp.asarray(rng.integers(0, 10, size=(48,)), jnp.int32)
idx = jnp.asarray(rng.integers(0, 48, size=(16,)), jnp.int32)
w = jnp.asarray(rng.normal(size=(24, 10)) * 0.1, jnp.float32)
mesh = jax.make_mesh((8, 1), ("data", "model"))
store = FeatureStore(feats, labels)
ref_l, ref_dw = jax.value_and_grad(
    lambda w: ops.fused_gather_loss_mean(feats, labels, idx, w))(w)
sl_l, sl_dw = jax.jit(jax.value_and_grad(
    lambda w: shard_local_fused_loss(store, idx, w, mesh)))(w)
rep["fused_loss"] = {
    "loss_diff": abs(float(sl_l) - float(ref_l)),
    "dw_maxdiff": float(jnp.max(jnp.abs(sl_dw - ref_dw))),
}
print(json.dumps(rep))
"""


def test_forced_8_device_scaling_golden():
    """All registered algorithms under donation + device-resident rounds
    on a forced 8-device host mesh agree with the donated unsharded run
    to reduction-noise tolerance and trace once; capacity 6 pads to 8
    without changing the drawn cohort; the fused shard-local loss is
    exact at 8 real shards."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath("src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, (
        f"scaling golden failed\nstdout: {proc.stdout[-3000:]}\n"
        f"stderr: {proc.stderr[-3000:]}")
    rep = json.loads(proc.stdout.strip().splitlines()[-1])
    assert rep["devices"] == 8
    for name, rec in rep["algos"].items():
        assert rec["traces"] == 1, name
        assert rec["diff"] <= 1e-5, (name, rec)
    pad = rep["padding"]
    assert pad["cohort_capacity"] == 6 and pad["padded_capacity"] == 8
    assert pad["live_prefix_equal"] and pad["mask_live"] == 6.0
    fl = rep["fused_loss"]
    assert fl["loss_diff"] <= 1e-6 and fl["dw_maxdiff"] <= 1e-6
