"""Calibration tests documenting the roofline methodology (DESIGN/§Roofline).

These pin the two empirical facts the analysis rests on:
  1. cost_analysis() is per-device under SPMD partitioning,
  2. XLA counts while bodies once; our loop-aware HLO model is exact
     on (nested) scan calibration cases.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import analyze_record, model_flops
from repro.utils.hlo_cost import module_cost


def _flops(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def test_cost_analysis_counts_scan_body_once():
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((256, 256), jnp.float32),
                         jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
                         ).compile()
    one = 2 * 256 ** 3
    assert _flops(c) == pytest.approx(one, rel=0.05)          # NOT 10x


def test_loop_aware_cost_counts_trips():
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((256, 256), jnp.float32),
                         jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
                         ).compile()
    mc = module_cost(c.as_text())
    assert mc.flops == pytest.approx(10 * 2 * 256 ** 3, rel=0.01)


def test_loop_aware_cost_nested_scans():
    def g(x, ws):
        def outer(c, _):
            def inner(ci, w):
                return ci @ w, ()
            c2, _ = jax.lax.scan(inner, c, ws)
            return c2, ()
        y, _ = jax.lax.scan(outer, x, jnp.zeros((3,)))
        return y

    c = jax.jit(g).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32),
                         jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
                         ).compile()
    mc = module_cost(c.as_text())
    assert mc.flops == pytest.approx(15 * 2 * 128 ** 3, rel=0.01)


def test_loop_aware_plain_dot_exact():
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((128, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile()
    assert module_cost(c.as_text()).flops == 2 * 128 * 64 * 32


def test_model_flops_moe_uses_active_params():
    dense = model_flops("phi3-mini-3.8b", "train_4k")
    moe = model_flops("olmoe-1b-7b", "train_4k")
    from repro.configs.registry import get_config
    olmoe = get_config("olmoe-1b-7b")
    assert olmoe.n_active_params() < 0.3 * olmoe.n_params()
    assert moe == pytest.approx(6.0 * olmoe.n_active_params() * 256 * 4096)
    assert dense > 0


def test_analyze_record_terms():
    rec = {
        "status": "ok", "arch": "phi3-mini-3.8b", "shape": "train_4k",
        "n_devices": 256,
        "loop_aware": {"flops": 1e14, "traffic_bytes": 1e12,
                       "collective_bytes": 5e10},
        "cost": {}, "collectives": {},
    }
    a = analyze_record(rec)
    assert a["t_compute_s"] == pytest.approx(1e14 / 197e12)
    assert a["t_memory_s"] == pytest.approx(1e12 / 819e9)
    assert a["t_collective_s"] == pytest.approx(5e10 / 50e9)
    assert a["dominant"] == "t_memory_s".replace("t_", "").replace("_s", "")
