"""The unified experiment API: registry, config round-trip, Engine-vs-
legacy equivalence, and grad clipping."""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Engine, ExperimentConfig, PROGRAMS, build_algorithm,
                       build_task, get_program, register_program)
from repro.api.phases import (ClientUpdate, Commit, ExtractFeatures,
                              FeatureGradients, RoundProgram, ServerUpdate)
from repro.core.algorithms import ALGORITHMS, make_algorithm
from repro.core.cyclesl import (CycleConfig, client_updates, cyclesl_round,
                                server_inner_loop)
from repro.core.feature_store import FeatureStore
from repro.core.protocol import broadcast_entity, init_entity
from repro.core.split import make_stage_task
from repro.data.federated import sample_cohort
from repro.models.cnn import mlp
from repro.optim import adam, sgd


# ---------------------------------------------------------------- registry
def test_all_algorithms_resolve_through_registry():
    assert sorted(ALGORITHMS) == sorted(PROGRAMS)
    assert len(PROGRAMS) == 10
    for name in ALGORITHMS:
        prog = get_program(name)
        assert prog.name == name
        assert prog.phases


def test_cycle_variants_are_baselines_with_server_phase_swapped():
    """The paper's drop-in claim, structurally: cyclesfl == sflv1 with the
    server phase swapped to the CycleSL inner loop and feature gradients
    taken at the UPDATED server."""
    for base, cyc in (("sflv1", "cyclesfl"), ("psl", "cyclepsl"),
                      ("sglr", "cyclesglr")):
        b, c = get_program(base), get_program(cyc)
        assert [type(p) for p in b.phases] == [type(p) for p in c.phases]
        sb = next(p for p in b.phases if isinstance(p, ServerUpdate))
        sc = next(p for p in c.phases if isinstance(p, ServerUpdate))
        assert sb.mode != "cycle" and sc.mode == "cycle"
        fb = next(p for p in b.phases if isinstance(p, FeatureGradients))
        fc = next(p for p in c.phases if isinstance(p, FeatureGradients))
        assert not fb.use_updated and fc.use_updated
        cb = next(p for p in b.phases if isinstance(p, Commit))
        cc = next(p for p in c.phases if isinstance(p, Commit))
        assert cb.mode == cc.mode


def test_register_program_guards_duplicates():
    prog = get_program("psl")
    with pytest.raises(ValueError):
        register_program(prog)
    with pytest.raises(KeyError):
        get_program("definitely-not-an-algo")


def test_make_algorithm_is_deprecated_shim():
    task = make_stage_task(mlp(8, [16], 4), cut=1, kind="xent")
    with pytest.warns(DeprecationWarning):
        algo = make_algorithm("cyclesfl", task, adam(1e-3), adam(1e-3))
    assert algo.uses_global_client


# ------------------------------------------------------------------ config
def test_experiment_config_dict_roundtrip():
    cfg = ExperimentConfig(
        algo="cyclesglr", task="gaze", rounds=7, n_clients=13,
        attendance=0.4, lr_server=3e-4, seed=5, round_key_salt=7919,
        cycle=CycleConfig(server_epochs=3, server_batch=32, grad_clip=0.5,
                          avg_client_grads=True))
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


def test_experiment_config_rejects_unknowns():
    with pytest.raises(KeyError):
        ExperimentConfig.from_dict({"not_a_field": 1})
    with pytest.raises(KeyError):
        ExperimentConfig(algo="nope").validate()
    with pytest.raises(KeyError):
        ExperimentConfig(task="nope").validate()


def test_experiment_config_from_flags():
    import argparse
    ap = argparse.ArgumentParser()
    ExperimentConfig.add_arguments(ap)
    args = ap.parse_args(["--algo", "sglr", "--rounds", "9",
                          "--server-epochs", "2", "--grad-clip", "0.1"])
    cfg = ExperimentConfig.from_flags(args)
    assert cfg.algo == "sglr" and cfg.rounds == 9
    assert cfg.cycle.server_epochs == 2 and cfg.cycle.grad_clip == 0.1


# ------------------------------------------- Engine vs legacy equivalence
class _Recorder:
    def __init__(self):
        self.rows = []
        self.state = None

    def on_round(self, engine, rnd, state, metrics):
        self.rows.append({k: float(v) for k, v in metrics.items()})
        self.state = state


def _legacy_loop(cfg, task, fed, with_mask=False):
    """The old hand-rolled driver, built on the deprecated shim.

    ``with_mask=True`` mirrors the Engine's padded-cohort protocol (an
    all-ones attendance mask at full capacity) — needed for the cycle
    algorithms, whose masked server resample plan is a different (shape-
    invariant) random stream than the dense unmasked plan.
    """
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        algo = make_algorithm(cfg.algo, task, adam(cfg.lr_server),
                              adam(cfg.lr_client), cfg.cycle)
    state = algo.init(jax.random.PRNGKey(cfg.seed), fed.n_clients)
    rng = np.random.default_rng(cfg.seed + 1)
    rows = []
    for rnd in range(cfg.rounds):
        cohort = sample_cohort(fed.n_clients, cfg.attendance, rng,
                               min_cohort=cfg.min_cohort)
        pairs = [fed.clients[c].sample_batch(rng, cfg.batch) for c in cohort]
        xs = jnp.asarray(np.stack([p[0] for p in pairs]))
        ys = jnp.asarray(np.stack([p[1] for p in pairs]))
        key = jax.random.PRNGKey(cfg.seed * cfg.round_key_salt + rnd)
        if with_mask:
            state, m = algo.round(state, jnp.asarray(cohort), xs, ys, key,
                                  jnp.ones(len(cohort), jnp.float32))
        else:
            state, m = algo.round(state, jnp.asarray(cohort), xs, ys, key)
        rows.append({k: float(v) for k, v in m.items()})
    return state, rows


def _checksum(tree):
    return float(sum(jnp.sum(jnp.abs(l.astype(jnp.float32)))
                     for l in jax.tree.leaves(tree)))


@pytest.mark.parametrize("algo", ["cyclesfl", "sglr"])
def test_engine_matches_legacy_path_round_for_round(algo):
    """Same seed, same task -> identical per-round metrics and final
    parameters for the Engine driver vs the legacy make_algorithm loop.

    sglr is compared against the truly unmasked legacy call, proving the
    Engine's padded execution (all-ones mask here: attendance * N is the
    capacity) is numerically transparent; cyclesfl mirrors the mask in
    the legacy loop because the cycle server phase's masked resample
    plan is a deliberately different random stream (see test_padded.py
    for the padded-vs-unpadded goldens).
    """
    task, fed, _ = build_task("image", 20, 0.5, 0, width=4, cut=2)
    cfg = ExperimentConfig(algo=algo, task="image", rounds=6, n_clients=20,
                           attendance=0.3, eval_every=6, width=4, seed=3)
    rec = _Recorder()
    Engine(cfg, task=task, fed=fed, callbacks=(rec,),
           log=lambda *a, **k: None).run()
    legacy_state, legacy_rows = _legacy_loop(cfg, task, fed,
                                             with_mask=(algo == "cyclesfl"))

    assert len(rec.rows) == len(legacy_rows) == cfg.rounds
    for got, want in zip(rec.rows, legacy_rows):
        assert sorted(got) == sorted(want)
        for k in want:
            # atol floor: sglr's feat_grad_norm_std is mathematically 0
            # (all cohort grads identical after averaging), so the two
            # summation orders differ only in ~1e-11 float noise
            np.testing.assert_allclose(got[k], want[k], rtol=1e-6,
                                       atol=1e-9, err_msg=f"{algo}:{k}")
    np.testing.assert_allclose(_checksum(rec.state.server.params),
                               _checksum(legacy_state.server.params),
                               rtol=1e-6)


def test_programs_match_pre_refactor_golden_metrics():
    """Guard against semantic drift in the phase rewrites: per-round
    metrics + final param checksums recorded from the pre-refactor
    closure implementations (the deleted ``_psl_round``/``_sglr_round``/
    etc.), all 10 algorithms, 5 rounds on a fixed mlp task.

    (The Engine-vs-legacy test above can't catch this — make_algorithm
    is now a shim over the same phases — so the old numbers are pinned
    as a golden file instead.)
    """
    golden_path = os.path.join(os.path.dirname(__file__), "golden",
                               "legacy_algorithm_metrics.json")
    with open(golden_path) as f:
        golden = json.load(f)
    task = make_stage_task(mlp(8, [16], 4), cut=1, kind="xent")
    rng = np.random.default_rng(0)
    C, b = 4, 8
    w = rng.normal(size=(8, 4))
    xs, ys = [], []
    for _ in range(C):
        x = rng.normal(size=(b, 8))
        xs.append(x)
        ys.append(np.argmax(x @ w, axis=-1))
    xs = jnp.asarray(np.stack(xs), jnp.float32)
    ys = jnp.asarray(np.stack(ys))
    opt = adam(5e-3)
    for name, rows in golden.items():
        algo = build_algorithm(get_program(name), task, opt, opt,
                               CycleConfig(server_epochs=2))
        state = algo.init(jax.random.PRNGKey(0), n_clients=C)
        for r, want in enumerate(rows[:-1]):
            state, m = algo.round(state, jnp.arange(C), xs, ys,
                                  jax.random.PRNGKey(r))
            for k, v in want.items():
                np.testing.assert_allclose(
                    float(m[k]), v, rtol=1e-3, atol=1e-6,
                    err_msg=f"{name} round {r}: {k}")
        want_ck = rows[-1]
        np.testing.assert_allclose(
            _checksum(state.server.params), want_ck["server_ck"],
            rtol=1e-3, err_msg=f"{name}: server params")
        got_clients = (state.clients if state.clients is not None
                       else state.client_global)
        np.testing.assert_allclose(
            _checksum(got_clients.params), want_ck["clients_ck"],
            rtol=1e-3, err_msg=f"{name}: client params")


# --------------------------------------------------------------- grad clip
@pytest.fixture(scope="module")
def clip_setup():
    task = make_stage_task(mlp(8, [16], 4), cut=1, kind="xent")
    rng = np.random.default_rng(0)
    C, b = 3, 8
    # large-scale inputs so raw gradients comfortably exceed the clip
    xs = jnp.asarray(rng.normal(size=(C, b, 8)) * 50, jnp.float32)
    ys = jnp.asarray(rng.integers(0, 4, size=(C, b)))
    return task, xs, ys


def test_client_updates_clip_bounds_grad_norms(clip_setup):
    task, xs, ys = clip_setup
    opt = sgd(0.1)
    clients = broadcast_entity(
        init_entity(task.init_client(jax.random.PRNGKey(1)), opt), 3)
    fgrads = jnp.asarray(np.random.default_rng(1).normal(
        size=(3, 8, 16)) * 10, jnp.float32)
    _, gnorms_raw = client_updates(task, clients, opt, xs, fgrads)
    assert float(jnp.max(gnorms_raw)) > 1e-2      # unclipped: big
    clip = 1e-2
    _, gnorms = client_updates(task, clients, opt, xs, fgrads,
                               grad_clip=clip)
    assert float(jnp.max(gnorms)) <= clip * (1 + 1e-5)


def test_server_inner_loop_clip_bounds_param_steps(clip_setup):
    """With SGD(lr=1) and clip c, each inner step moves the server params
    by at most c in global norm -> total drift <= steps * c."""
    task, xs, ys = clip_setup
    opt = sgd(1.0)
    server = init_entity(task.init_server(jax.random.PRNGKey(0)), opt)
    feats = jax.vmap(lambda x: task.client_forward(
        task.init_client(jax.random.PRNGKey(1)), x))(xs)
    store = FeatureStore.pool(feats, ys)
    clip = 1e-3
    ccfg = CycleConfig(server_epochs=2, grad_clip=clip)
    server2, _ = server_inner_loop(task, server, opt, store,
                                   jax.random.PRNGKey(2), ccfg, batch=8)
    steps = int(server2.step)
    drift = jnp.sqrt(sum(
        jnp.sum(jnp.square(a - b)) for a, b in
        zip(jax.tree.leaves(server2.params), jax.tree.leaves(server.params))))
    assert steps > 0
    assert float(drift) <= steps * clip * (1 + 1e-4)
    # and the unclipped loop drifts much further
    server3, _ = server_inner_loop(task, server, opt, store,
                                   jax.random.PRNGKey(2),
                                   CycleConfig(server_epochs=2), batch=8)
    drift_raw = jnp.sqrt(sum(
        jnp.sum(jnp.square(a - b)) for a, b in
        zip(jax.tree.leaves(server3.params), jax.tree.leaves(server.params))))
    assert float(drift_raw) > float(drift) * 10


def test_cyclesl_round_respects_grad_clip(clip_setup):
    task, xs, ys = clip_setup
    opt = sgd(0.1)
    server = init_entity(task.init_server(jax.random.PRNGKey(0)), opt)
    clients = broadcast_entity(
        init_entity(task.init_client(jax.random.PRNGKey(1)), opt), 3)
    clip = 1e-3
    _, _, metrics = cyclesl_round(task, server, clients, opt, opt, xs, ys,
                                  jax.random.PRNGKey(2),
                                  CycleConfig(grad_clip=clip))
    assert float(metrics["client_grad_norm_mean"]) <= clip * (1 + 1e-5)


# ------------------------------------------------------------------ engine
def test_engine_runs_every_registered_algorithm():
    """Every registry entry compiles and learns through the one driver."""
    task = make_stage_task(mlp(8, [32], 4), cut=1, kind="xent")
    rng = np.random.default_rng(0)
    C, b = 4, 32
    w = rng.normal(size=(8, 4))
    xs, ys = [], []
    for _ in range(C):
        x = rng.normal(size=(b, 8))
        xs.append(x)
        ys.append(np.argmax(x @ w, axis=-1))
    xs = jnp.asarray(np.stack(xs), jnp.float32)
    ys = jnp.asarray(np.stack(ys))
    opt = adam(5e-3)
    for name in PROGRAMS:
        algo = build_algorithm(get_program(name), task, opt, opt,
                               CycleConfig(server_epochs=1))
        state = algo.init(jax.random.PRNGKey(0), n_clients=C)
        first = None
        for r in range(15):
            state, m = algo.round(state, jnp.arange(C), xs, ys,
                                  jax.random.PRNGKey(r))
            if first is None:
                first = float(m["server_loss"])
        assert float(m["server_loss"]) < first, name
