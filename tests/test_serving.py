"""Continuous-batching serve runtime contracts.

The load-bearing claims, each pinned here:

* compile-once — ONE jitted trace each for prefill / admit / decode
  across wildly different arrival patterns on one runtime;
* slot-reuse correctness — a retired slot's ring-buffer cache never
  leaks into the next request admitted to that slot (bit-for-bit a
  fresh runtime);
* batched prefill — the single scanned prefill dispatch is bit-equal
  to stepping the prompt per-token through the same decode body;
* deadlines — expired queued requests are rejected without compute,
  expired in-flight requests are evicted with their partial output;
* retry/backoff — failed dispatches retry on the exponential-backoff
  schedule, and exhaustion evicts only the affected work, leaving the
  runtime serving.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.config import ExperimentConfig
from repro.configs.gemma2_2b import smoke as gemma_smoke
from repro.configs.mamba2_2p7b import smoke as mamba_smoke
from repro.models.transformer import Transformer
from repro.serve import (ServeConfig, ServeDispatchError, ServeRuntime,
                         STATUS_DONE, STATUS_EVICTED_DEADLINE,
                         STATUS_EVICTED_FAILURE, STATUS_REJECTED,
                         make_prompts, run_closed_loop)

pytestmark = pytest.mark.serving


class FakeClock:
    """Deterministic injectable clock; sleeps advance it."""

    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt

    def sleep(self, dt):
        self.sleeps.append(dt)
        self.t += dt


SC = ServeConfig(slots=4, max_prompt_len=6, max_new_tokens=5,
                 prefill_batch=2)


@pytest.fixture(scope="module")
def arch():
    return gemma_smoke()


@pytest.fixture(scope="module")
def runtime(arch):
    """One module-scoped runtime — reused so the trace counters span
    every arrival pattern the tests throw at it."""
    return ServeRuntime(arch, SC, seed=0)


def _greedy_reference(rt, prompt, n_new):
    """Per-token reference: the legacy serve loop's exact computation."""
    arch, sc = rt.arch, rt.serve
    state = Transformer.init_decode_state(
        arch, 1, sc.max_prompt_len + sc.max_new_tokens)
    step = jax.jit(lambda p, t, s: Transformer.decode_step(p, arch, t, s))
    logits = None
    for t in (list(prompt) or [0]):
        logits, state = step(rt.params, jnp.asarray([[t]], jnp.int32), state)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_new - 1):
        logits, state = step(rt.params,
                             jnp.asarray([[out[-1]]], jnp.int32), state)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_compile_once_across_arrival_patterns(runtime):
    rt = runtime
    # pattern 1: sequential singles
    for i in range(3):
        rt.submit([1 + i], max_new=2)
        rt.drain()
    # pattern 2: a burst over capacity (queueing + slot reuse)
    for i in range(9):
        rt.submit([2, 3, 4][: 1 + i % 3], max_new=3)
    rt.drain()
    # pattern 3: staggered arrivals mid-flight
    rt.submit([5, 6], max_new=4)
    rt.step()
    rt.submit([7], max_new=2)
    rt.step()
    rt.submit([1, 2, 3, 4, 5, 6], max_new=3)
    rt.drain()
    assert all(r.status == STATUS_DONE for r in rt.results.values())
    # THE claim: one trace per jitted site, regardless of arrivals
    assert rt.traces == {"prefill": 1, "admit": 1, "decode": 1}
    assert rt.stats()["max_slot_reuse"] > 1


def test_output_matches_per_token_reference(runtime):
    rt = runtime
    prompts = [[1, 2, 3], [9], [4, 5, 6, 7, 8, 2]]
    rids = [rt.submit(p, max_new=4) for p in prompts]
    rt.drain()
    for p, rid in zip(prompts, rids):
        assert rt.results[rid].tokens.tolist() == \
            _greedy_reference(rt, p, 4), p


def test_empty_prompt_is_bos_zero(runtime):
    rid = runtime.submit([], max_new=3)
    runtime.drain()
    assert runtime.results[rid].tokens.tolist() == \
        _greedy_reference(runtime, [], 3)


def test_slot_reuse_never_leaks(arch):
    """A request served in a REUSED slot is bit-for-bit a fresh runtime:
    the ring-buffer position reset invalidates every stale cache entry
    the previous occupant left (no cache zeroing dispatch exists)."""
    sc = ServeConfig(slots=1, max_prompt_len=6, max_new_tokens=5,
                     prefill_batch=1)
    rt = ServeRuntime(arch, sc, seed=0)
    # occupant 1 fills the slot's cache to a different occupancy/content
    rt.submit([3, 1, 4, 1, 5, 9], max_new=5)
    rt.drain()
    # occupant 2 reuses slot 0
    rid = rt.submit([2, 7], max_new=5)
    rt.drain()
    assert rt.assignments[0] == 2
    fresh = ServeRuntime(arch, sc, seed=0)
    frid = fresh.submit([2, 7], max_new=5)
    fresh.drain()
    assert rt.results[rid].tokens.tolist() == \
        fresh.results[frid].tokens.tolist()


def test_batched_prefill_bit_equals_per_token(runtime):
    """One scanned chunk with MIXED lengths vs per-token stepping of
    each row through the same vmapped body."""
    rt = runtime
    tokens = np.zeros((SC.prefill_batch, SC.max_prompt_len), np.int32)
    rows = [[3, 1, 4, 1, 5], [2, 7, 1]]
    lens = np.asarray([len(r) for r in rows], np.int32)
    for i, r in enumerate(rows):
        tokens[i, :len(r)] = r
    (cstate, first), _ = rt._dispatch(
        "prefill", rt._prefill, rt.params, jnp.asarray(tokens),
        jnp.asarray(lens), rt._chunk_zero)
    # reference: step each row alone per-token (vmap rows are
    # independent, so a singleton runtime is an exact reference)
    for i, row in enumerate(rows):
        assert int(first[i]) == _greedy_reference(rt, row, 1)[0], i
    # the prefilled state must carry the row's true length as pos
    pos = np.asarray(jax.device_get(cstate["pos"]))
    assert pos.tolist() == lens.tolist()


def test_deadline_rejects_queued_and_evicts_inflight(arch):
    clk = FakeClock()
    sc = ServeConfig(slots=1, max_prompt_len=4, max_new_tokens=8,
                     prefill_batch=1, deadline_s=100.0)
    rt = ServeRuntime(arch, sc, seed=0, clock=clk, sleep=clk.sleep)
    slow = rt.submit([1, 2], deadline_s=5.0)     # will expire in flight
    queued = rt.submit([3], deadline_s=5.0)      # will expire queued
    rt.step()                                    # admits `slow` only
    assert rt.results[slow].status == "running"
    clk.advance(10.0)                            # both deadlines pass
    rt.step()
    assert rt.results[slow].status == STATUS_EVICTED_DEADLINE
    assert len(rt.results[slow].tokens) > 0      # partial output kept
    assert rt.results[queued].status == STATUS_REJECTED
    assert len(rt.results[queued].tokens) == 0   # zero compute spent
    # the slot is free again and the runtime keeps serving
    ok = rt.submit([4], max_new=2)
    rt.drain()
    assert rt.results[ok].status == STATUS_DONE


def test_done_requests_honor_deadline(arch):
    """No request completes past its deadline: generous deadlines all
    finish in time, and every finish timestamp is within bound."""
    clk = FakeClock()
    rt = ServeRuntime(arch, SC, seed=0, clock=clk, sleep=clk.sleep)
    rids = [rt.submit([i + 1], max_new=3, deadline_s=1e6) for i in range(6)]
    while any(rt.results[r].status not in (STATUS_DONE,) for r in rids):
        rt.step()
        clk.advance(0.01)
    for r in rids:
        req = rt.results[r]
        assert req.finished <= req.deadline


def test_retry_backoff_schedule(arch):
    """A dispatch that fails twice then succeeds: the injected sleeps
    follow backoff_base * 2^attempt and the request still completes."""
    clk = FakeClock()
    fails = {"n": 0}

    def hook(site, tick, attempt):
        if site == "decode" and fails["n"] < 2:
            fails["n"] += 1
            raise RuntimeError("injected stall")

    sc = ServeConfig(slots=2, max_prompt_len=4, max_new_tokens=3,
                     prefill_batch=1, max_retries=3, backoff_base_s=0.5)
    rt = ServeRuntime(arch, sc, seed=0, clock=clk, sleep=clk.sleep,
                      fault_hook=hook)
    rid = rt.submit([1, 2], max_new=3)
    rt.drain()
    assert rt.results[rid].status == STATUS_DONE
    assert clk.sleeps == [0.5, 1.0]          # base * 2^0, base * 2^1
    assert rt.dispatch_retries == 2
    assert rt.results[rid].retries >= 2


def test_decode_exhaustion_evicts_live_and_recovers(arch):
    """Decode retry exhaustion evicts every live slot with its partial
    output; the runtime immediately serves new requests."""
    state = {"kill": True}

    def hook(site, tick, attempt):
        if site == "decode" and state["kill"]:
            raise RuntimeError("persistent decode fault")

    sc = ServeConfig(slots=2, max_prompt_len=4, max_new_tokens=3,
                     prefill_batch=2, max_retries=1)
    rt = ServeRuntime(arch, sc, seed=0, fault_hook=hook)
    rids = [rt.submit([1 + i], max_new=3) for i in range(2)]
    rt.step()
    for r in rids:
        req = rt.results[r]
        assert req.status == STATUS_EVICTED_FAILURE
        assert len(req.tokens) == 1          # the prefill's first token
    assert rt.evictions["failure"] == 2
    state["kill"] = False
    ok = rt.submit([5], max_new=2)
    rt.drain()
    assert rt.results[ok].status == STATUS_DONE


def test_prefill_exhaustion_evicts_chunk_only(arch):
    def hook(site, tick, attempt):
        if site == "prefill":
            raise RuntimeError("persistent prefill fault")

    sc = ServeConfig(slots=2, max_prompt_len=4, max_new_tokens=2,
                     prefill_batch=2, max_retries=0)
    rt = ServeRuntime(arch, sc, seed=0, fault_hook=hook)
    rids = [rt.submit([1]), rt.submit([2])]
    rt.step()
    assert all(rt.results[r].status == STATUS_EVICTED_FAILURE
               for r in rids)
    assert rt.n_live == 0 and len(rt.free) == 2  # slots returned


def test_closed_loop_loadgen(arch):
    rt = ServeRuntime(arch, SC, seed=0)
    prompts = make_prompts(8, SC.max_prompt_len, arch.vocab, seed=3)
    row = run_closed_loop(rt, prompts, concurrency=3)
    assert row["by_status"][STATUS_DONE] == 8
    assert row["throughput_tok_s"] > 0
    assert row["latency_s"]["p50"] is not None
    assert row["latency_s"]["p50"] <= row["latency_s"]["p99"]


def test_closed_loop_empty_prompts_on_reused_runtime(arch):
    """Regression: the loadgen used to recover its requests with a tail
    slice ``results[-len(prompts):]`` — for an EMPTY prompt list that
    slice is the runtime's whole shared history, so a reused runtime
    reported the previous call's counts.  Requests are now selected by
    the ids this call submitted."""
    rt = ServeRuntime(arch, SC, seed=0)
    prompts = make_prompts(4, SC.max_prompt_len, arch.vocab, seed=3)
    warm = run_closed_loop(rt, prompts, concurrency=2)
    assert warm["by_status"][STATUS_DONE] == 4
    row = run_closed_loop(rt, [], concurrency=2)
    assert row["n_requests"] == 0
    assert all(v == 0 for v in row["by_status"].values()), row["by_status"]
    assert row["throughput_tok_s"] == 0.0
    assert row["throughput_req_s"] == 0.0
    assert row["latency_s"]["p50"] is None


def test_mamba2_runtime(arch):
    m = mamba_smoke()
    sc = ServeConfig(slots=2, max_prompt_len=4, max_new_tokens=3,
                     prefill_batch=2)
    rt = ServeRuntime(m, sc, seed=0)
    rids = [rt.submit([1, 2], max_new=3), rt.submit([3], max_new=2)]
    rt.drain()
    assert all(rt.results[r].status == STATUS_DONE for r in rids)
    assert rt.traces == {"prefill": 1, "admit": 1, "decode": 1}


def test_serve_config_validation_and_roundtrip():
    sc = ServeConfig(slots=16, deadline_s=2.5, max_retries=1)
    assert ServeConfig.from_dict(sc.to_dict()) == sc
    with pytest.raises(KeyError):
        ServeConfig.from_dict({"bogus": 1})
    with pytest.raises(ValueError):
        ServeConfig(prefill_batch=9, slots=8).validate()
    with pytest.raises(ValueError):
        ServeConfig(deadline_s=0.0).validate()
    cfg = ExperimentConfig(serve=sc)
    rt = ExperimentConfig.from_dict(cfg.to_dict())
    assert rt.serve == sc
    # pre-serve configs load with default knobs
    d = cfg.to_dict()
    d.pop("serve")
    assert ExperimentConfig.from_dict(d).serve == ServeConfig()


def test_submit_rejects_over_budget(runtime):
    with pytest.raises(ValueError):
        runtime.submit(list(range(SC.max_prompt_len + 1)))
    with pytest.raises(ValueError):
        runtime.submit([1], max_new=SC.max_new_tokens + 1)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices for the serve mesh")
def test_mesh_placement_matches_host(arch):
    from repro.launch.mesh import make_engine_mesh
    sc = ServeConfig(slots=8, max_prompt_len=4, max_new_tokens=3,
                     prefill_batch=4)
    mesh = make_engine_mesh((4, 2), ("data", "model"))
    rt = ServeRuntime(arch, sc, seed=0, mesh=mesh)
    host = ServeRuntime(arch, sc, seed=0)
    prompts = [[1 + i, 2, 3][: 1 + i % 3] for i in range(10)]
    for r in (rt, host):
        for p in prompts:
            r.submit(p, max_new=3)
        r.drain()
    for a, b in zip(sorted(rt.results), sorted(host.results)):
        assert rt.results[a].tokens.tolist() == \
            host.results[b].tokens.tolist()
    assert rt.traces == {"prefill": 1, "admit": 1, "decode": 1}
    # the slot table actually carries the decode-state placement
    spec = rt.state["kv"].k.sharding.spec
    assert tuple(spec) == (None, "data", None, "model", None)


# ---------------------------------------------------------------- legacy
# launch/serve.py edge-case guards (the --steps 0 / --prompt-len 0 fixes)

def test_legacy_serve_steps_zero(arch):
    from repro.launch.serve import serve_decoder_only
    res = serve_decoder_only(arch, batch=2, prompt_len=0, steps=0)
    assert res["tokens"].shape == (2, 0)
    assert res["decode_s_per_token"] == 0.0
    res = serve_decoder_only(arch, batch=2, prompt_len=3, steps=0)
    assert res["tokens"].shape == (2, 0)
    with pytest.raises(ValueError):
        serve_decoder_only(arch, batch=2, prompt_len=-1, steps=1)


def test_legacy_serve_whisper_steps_zero():
    from repro.configs.whisper_base import smoke as wsmoke
    from repro.launch.serve import serve_whisper
    res = serve_whisper(wsmoke(), batch=2, steps=0)
    assert res["tokens"].shape == (2, 0)
    assert res["decode_s_per_token"] == 0.0
