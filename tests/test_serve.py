"""Smoke tests for both split-serving paths (launch/serve.py).

The prefill satellite (ISSUE 4): the prompt is prefilled through the
SAME jitted decode step the generation loop uses — one trace for the
whole serve call — so ``prefill_s`` measures the model, not per-token
retrace overhead.  These tests pin both serve paths end-to-end on the
smoke-sized archs.
"""
import jax
import numpy as np

from repro.configs.registry import smoke_config
from repro.launch.serve import serve_decoder_only, serve_whisper
from repro.models.transformer import Transformer


def test_serve_decoder_only_smoke():
    cfg = smoke_config("gemma2-2b")
    res = serve_decoder_only(cfg, batch=2, prompt_len=4, steps=3)
    toks = np.asarray(res.pop("tokens"))
    assert toks.shape == (2, 3)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()
    assert res["prefill_s"] >= 0.0 and res["decode_s_per_token"] > 0.0
    assert res["batch"] == 2


def test_serve_whisper_smoke():
    cfg = smoke_config("whisper-base")
    res = serve_whisper(cfg, batch=2, steps=3)
    toks = np.asarray(res.pop("tokens"))
    assert toks.shape == (2, 3)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()
    assert res["decode_s_per_token"] > 0.0


def test_prefill_uses_jitted_decode_step():
    """The fixed prefill loop must not retrace per token: stepping a
    prompt of any length through the serve path compiles the decode
    step exactly once (the bug was an uncompiled Transformer.decode_step
    call per prompt token, so prefill_s measured trace overhead)."""
    cfg = smoke_config("gemma2-2b")
    traces = {"n": 0}
    orig = Transformer.decode_step

    def counting(params, c, tok, state, **kw):
        traces["n"] += 1             # trace-time only under jit
        return orig(params, c, tok, state, **kw)

    Transformer.decode_step = staticmethod(counting)
    try:
        serve_decoder_only(cfg, batch=2, prompt_len=6, steps=2)
    finally:
        Transformer.decode_step = staticmethod(orig)
    assert traces["n"] == 1, (
        f"decode step traced/called {traces['n']} times for a 6-token "
        "prefill + 2-step decode — prefill is not going through the "
        "jitted step")
