"""Per-kernel correctness: sweep shapes/dtypes, assert_allclose vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.feature_resample import feature_resample
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gather_loss import gather_loss_microbatch
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.topk_gating import topk_gating

pytestmark = pytest.mark.kernels

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


FA_CASES = [
    # B, Sq, Sk, H, Hkv, D, causal, window, softcap
    (1, 128, 128, 4, 4, 64, True, None, None),
    (2, 128, 128, 4, 2, 64, True, None, None),       # GQA
    (1, 256, 256, 8, 1, 32, True, None, None),       # MQA
    (2, 128, 128, 4, 4, 64, True, 32, None),         # sliding window
    (1, 128, 128, 4, 2, 64, True, None, 50.0),       # softcap (gemma2)
    (1, 64, 64, 2, 2, 128, False, None, None),       # bidirectional
    (1, 192, 192, 4, 4, 64, True, 64, 30.0),         # window+cap
]


@pytest.mark.parametrize("case", FA_CASES, ids=[str(c) for c in FA_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, Sq, Sk, H, Hkv, D, causal, window, cap = case
    q = _rand((B, Sq, H, D), dtype)
    k = _rand((B, Sk, Hkv, D), dtype)
    v = _rand((B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("blocks", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shape_invariance(blocks):
    bq, bk = blocks
    q = _rand((1, 128, 4, 64), jnp.float32)
    k = _rand((1, 128, 2, 64), jnp.float32)
    v = _rand((1, 128, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


SSD_CASES = [
    # B, L, H, P, N, chunk
    (1, 128, 2, 32, 16, 32),
    (2, 256, 3, 64, 32, 64),
    (1, 64, 1, 16, 8, 64),      # single chunk
    (2, 128, 4, 32, 16, 128),   # chunk == L
]


@pytest.mark.parametrize("case", SSD_CASES, ids=[str(c) for c in SSD_CASES])
def test_ssd_scan_vs_ref(case):
    B, L, H, P, N, chunk = case
    x = _rand((B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand((B, L, H), jnp.float32))
    A = -jnp.exp(_rand((H,), jnp.float32))
    Bm = _rand((B, L, H, N), jnp.float32)
    Cm = _rand((B, L, H, N), jnp.float32)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    want, _ = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=5e-3, rtol=5e-3)


def test_ssd_scan_matches_model_chunked():
    """Kernel also agrees with the chunked model implementation."""
    from repro.models.mamba2 import ssd_chunked
    B, L, H, P, N = 1, 128, 2, 32, 16
    x = _rand((B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand((B, L, H), jnp.float32))
    A = -jnp.exp(_rand((H,), jnp.float32))
    Bm = _rand((B, L, 1, N), jnp.float32)       # grouped
    Cm = _rand((B, L, 1, N), jnp.float32)
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    BmH = jnp.repeat(Bm, H, axis=2)
    CmH = jnp.repeat(Cm, H, axis=2)
    y_kernel = ssd_scan(x, dt, A, BmH, CmH, chunk=32)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=5e-3, rtol=5e-3)


@pytest.mark.parametrize("T,E,k,bt", [(256, 8, 2, 64), (512, 64, 8, 128),
                                      (128, 4, 4, 128), (1024, 16, 1, 256)])
def test_topk_gating_vs_ref(T, E, k, bt):
    logits = _rand((T, E), jnp.float32)
    w, ids = topk_gating(logits, k, block_t=min(bt, T))
    wr, ir = ref.topk_gating_ref(logits, k)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ir))


@pytest.mark.parametrize("T,D,M", [(64, 32, 64), (300, 128, 128), (128, 8, 37)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_feature_resample_vs_ref(T, D, M, dtype):
    src = jnp.asarray(RNG.normal(size=(T, D)) * 10, dtype)
    idx = jnp.asarray(RNG.integers(0, T, size=M), jnp.int32)
    out = feature_resample(src, idx)
    want = ref.feature_resample_ref(src, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ------------------------------------------------ resample_rows (nd wrapper)
@pytest.mark.parametrize("trailing", [(), (8,), (3, 5), (2, 3, 4)],
                         ids=["1d", "2d", "3d", "4d"])
@pytest.mark.parametrize("T,M", [(37, 16), (300, 64), (128, 37), (64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_resample_rows_vs_ref(trailing, T, M, dtype):
    """The nd row-gather entry point FeatureStore dispatches to, across
    dtypes, non-power-of-two row counts, and >2-D trailing shapes — in
    interpret mode on CPU (the validated kernel fallback)."""
    src = jnp.asarray(RNG.normal(size=(T,) + trailing) * 10, dtype)
    idx = jnp.asarray(RNG.integers(0, T, size=M), jnp.int32)
    out = ops.resample_rows(src, idx)
    want = jnp.take(src, idx, axis=0)
    assert out.dtype == src.dtype and out.shape == (M,) + trailing
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# --------------------------------------------------- fused gather + loss
GL_CASES = [
    # T, D, K, M (non-power-of-two rows, narrow/wide heads, M != T)
    (37, 16, 5, 12),
    (300, 24, 3, 50),
    (64, 8, 10, 64),
    (128, 33, 7, 19),
]


@pytest.mark.parametrize("case", GL_CASES, ids=[str(c) for c in GL_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bias", [False, True])
def test_gather_loss_microbatch_vs_ref(case, dtype, bias):
    T, D, K, M = case
    src = jnp.asarray(RNG.normal(size=(T, D)), dtype)
    labels = jnp.asarray(RNG.integers(0, K, size=T), jnp.int32)
    idx = jnp.asarray(RNG.integers(0, T, size=M), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(D, K)) * 0.3, dtype)
    b = (jnp.asarray(RNG.normal(size=(K,)), jnp.float32) if bias else None)
    out = gather_loss_microbatch(src, labels, idx, w, b, interpret=True)
    want = ref.gather_loss_microbatch_ref(src, labels, idx, w, b)
    assert out.dtype == jnp.float32 and out.shape == (M,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("trailing", [(6,), (4, 3), (2, 3, 2)],
                         ids=["2d", "3d", "4d"])
def test_gather_loss_ops_wrapper_flattens_trailing_shapes(trailing):
    """ops.gather_loss_microbatch flattens [T, ...] rows exactly like the
    head's ``x.reshape(B, -1)`` before the matmul."""
    import math
    T, K, M = 40, 7, 20
    D = math.prod(trailing)
    src = jnp.asarray(RNG.normal(size=(T,) + trailing), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, K, size=T), jnp.int32)
    idx = jnp.asarray(RNG.integers(0, T, size=M), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(D, K)) * 0.3, jnp.float32)
    out = ops.gather_loss_microbatch(src, labels, idx, w)
    want = ref.gather_loss_microbatch_ref(src.reshape(T, -1), labels, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_fused_gather_loss_mean_value_and_grad_match_ref():
    """The custom_vjp wrapper: forward equals the unfused
    gather-then-xent mean, backward equals autodiff through the ref —
    the contract that lets the server inner loop train on the fused
    kernel."""
    from repro.core.split import xent_loss
    T, D, K, M = 48, 12, 5, 16
    src = jnp.asarray(RNG.normal(size=(T, D)), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, K, size=T), jnp.int32)
    idx = jnp.asarray(RNG.integers(0, T, size=M), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(D, K)) * 0.3, jnp.float32)

    def unfused(w):
        f = jnp.take(src, idx, axis=0)
        return xent_loss(f @ w, jnp.take(labels, idx, axis=0))

    val, grad = jax.value_and_grad(
        lambda w: ops.fused_gather_loss_mean(src, labels, idx, w))(w)
    want_val, want_grad = jax.value_and_grad(unfused)(w)
    np.testing.assert_allclose(float(val), float(want_val), atol=1e-6)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(want_grad),
                               atol=1e-5)


def test_fused_gather_loss_round_matches_classic_path():
    """Round-level golden for CycleConfig.fused_gather_loss: on a
    last-cut linear-head task the fused inner loop must (a) actually
    engage (server_head threaded by make_stage_task) and (b) train to
    the same state/metrics as the classic gather-then-loss path, masked
    and unmasked — while a mid-cut task keeps server_head None and the
    knob bit-for-bit inert."""
    from repro.api import build_algorithm, get_program
    from repro.core.cyclesl import CycleConfig
    from repro.core.split import make_stage_task
    from repro.models.cnn import mlp
    from repro.optim import adam

    rng = np.random.default_rng(11)
    C, B = 6, 8
    model = mlp(8, [16], 4)
    xs = jnp.asarray(rng.normal(size=(C, B, 8)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, 4, size=(C, B)))
    opt = adam(5e-3)

    def drive(task, fused, mask):
        algo = build_algorithm(
            get_program("cyclesfl"), task, opt, opt,
            CycleConfig(server_epochs=2, fused_gather_loss=fused))
        state = algo.init(jax.random.PRNGKey(0), n_clients=C)
        args = (state, jnp.arange(C), xs, ys, jax.random.PRNGKey(1))
        return algo.round(*args, mask) if mask is not None else \
            algo.round(*args)

    head_task = make_stage_task(model, cut=model.n_stages - 1, kind="xent")
    assert head_task.server_head is not None       # fusion engages
    for mask in (None, jnp.asarray([1, 1, 1, 1, 0, 0], jnp.float32)):
        s_off, m_off = drive(head_task, False, mask)
        s_on, m_on = drive(head_task, True, mask)
        np.testing.assert_allclose(float(m_on["server_loss"]),
                                   float(m_off["server_loss"]), atol=1e-6)
        for a, b in zip(jax.tree.leaves(s_off), jax.tree.leaves(s_on)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-5)

    deep = mlp(8, [16, 12], 4)                     # 3 stages
    mid_task = make_stage_task(deep, cut=1, kind="xent")
    assert mid_task.server_head is None            # multi-stage server
    s_off, m_off = drive(mid_task, False, None)
    s_on, m_on = drive(mid_task, True, None)       # knob inert: same path
    assert float(m_on["server_loss"]) == float(m_off["server_loss"])
    for a, b in zip(jax.tree.leaves(s_off), jax.tree.leaves(s_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("shape,step,wd", [((64,), 0, 0.0), ((33, 7), 5, 0.0),
                                           ((128, 16), 100, 0.01),
                                           ((70001,), 3, 0.0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_adam_vs_ref(shape, step, wd, dtype):
    from repro.kernels.fused_adam import fused_adam
    p = jnp.asarray(RNG.normal(size=shape), dtype)
    g = jnp.asarray(RNG.normal(size=shape), dtype)
    m = jnp.asarray(RNG.normal(size=shape) * 0.1, jnp.float32)
    v = jnp.asarray(np.abs(RNG.normal(size=shape)) * 0.1, jnp.float32)
    p2, m2, v2 = fused_adam(p, g, m, v, step, lr=1e-3, weight_decay=wd,
                            block=4096)
    pr, mr, vr = ref.fused_adam_ref(p, g, m, v, step, lr=1e-3,
                                    weight_decay=wd)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(p2, np.float32),
                               np.asarray(pr, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(vr), atol=1e-6)


def test_fused_adam_matches_optim_adam():
    """The kernel implements exactly repro.optim.adam's update rule."""
    from repro.kernels.fused_adam import fused_adam
    from repro.optim import adam
    from repro.optim.optimizer import apply_updates
    opt = adam(3e-3)
    params = {"w": jnp.asarray(RNG.normal(size=(31,)), jnp.float32)}
    grads = {"w": jnp.asarray(RNG.normal(size=(31,)), jnp.float32)}
    state = opt.init(params)
    upd, state2 = opt.update(grads, state, params, 7)
    want = apply_updates(params, upd)
    p2, m2, v2 = fused_adam(params["w"], grads["w"], state["m"]["w"],
                            state["v"]["w"], 7, lr=3e-3, block=64)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(want["w"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(state2["m"]["w"]),
                               atol=1e-7)
