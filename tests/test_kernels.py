"""Per-kernel correctness: sweep shapes/dtypes, assert_allclose vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.feature_resample import feature_resample
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.topk_gating import topk_gating

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


FA_CASES = [
    # B, Sq, Sk, H, Hkv, D, causal, window, softcap
    (1, 128, 128, 4, 4, 64, True, None, None),
    (2, 128, 128, 4, 2, 64, True, None, None),       # GQA
    (1, 256, 256, 8, 1, 32, True, None, None),       # MQA
    (2, 128, 128, 4, 4, 64, True, 32, None),         # sliding window
    (1, 128, 128, 4, 2, 64, True, None, 50.0),       # softcap (gemma2)
    (1, 64, 64, 2, 2, 128, False, None, None),       # bidirectional
    (1, 192, 192, 4, 4, 64, True, 64, 30.0),         # window+cap
]


@pytest.mark.parametrize("case", FA_CASES, ids=[str(c) for c in FA_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, Sq, Sk, H, Hkv, D, causal, window, cap = case
    q = _rand((B, Sq, H, D), dtype)
    k = _rand((B, Sk, Hkv, D), dtype)
    v = _rand((B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("blocks", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shape_invariance(blocks):
    bq, bk = blocks
    q = _rand((1, 128, 4, 64), jnp.float32)
    k = _rand((1, 128, 2, 64), jnp.float32)
    v = _rand((1, 128, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


SSD_CASES = [
    # B, L, H, P, N, chunk
    (1, 128, 2, 32, 16, 32),
    (2, 256, 3, 64, 32, 64),
    (1, 64, 1, 16, 8, 64),      # single chunk
    (2, 128, 4, 32, 16, 128),   # chunk == L
]


@pytest.mark.parametrize("case", SSD_CASES, ids=[str(c) for c in SSD_CASES])
def test_ssd_scan_vs_ref(case):
    B, L, H, P, N, chunk = case
    x = _rand((B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand((B, L, H), jnp.float32))
    A = -jnp.exp(_rand((H,), jnp.float32))
    Bm = _rand((B, L, H, N), jnp.float32)
    Cm = _rand((B, L, H, N), jnp.float32)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    want, _ = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=5e-3, rtol=5e-3)


def test_ssd_scan_matches_model_chunked():
    """Kernel also agrees with the chunked model implementation."""
    from repro.models.mamba2 import ssd_chunked
    B, L, H, P, N = 1, 128, 2, 32, 16
    x = _rand((B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand((B, L, H), jnp.float32))
    A = -jnp.exp(_rand((H,), jnp.float32))
    Bm = _rand((B, L, 1, N), jnp.float32)       # grouped
    Cm = _rand((B, L, 1, N), jnp.float32)
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    BmH = jnp.repeat(Bm, H, axis=2)
    CmH = jnp.repeat(Cm, H, axis=2)
    y_kernel = ssd_scan(x, dt, A, BmH, CmH, chunk=32)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=5e-3, rtol=5e-3)


@pytest.mark.parametrize("T,E,k,bt", [(256, 8, 2, 64), (512, 64, 8, 128),
                                      (128, 4, 4, 128), (1024, 16, 1, 256)])
def test_topk_gating_vs_ref(T, E, k, bt):
    logits = _rand((T, E), jnp.float32)
    w, ids = topk_gating(logits, k, block_t=min(bt, T))
    wr, ir = ref.topk_gating_ref(logits, k)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ir))


@pytest.mark.parametrize("T,D,M", [(64, 32, 64), (300, 128, 128), (128, 8, 37)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_feature_resample_vs_ref(T, D, M, dtype):
    src = jnp.asarray(RNG.normal(size=(T, D)) * 10, dtype)
    idx = jnp.asarray(RNG.integers(0, T, size=M), jnp.int32)
    out = feature_resample(src, idx)
    want = ref.feature_resample_ref(src, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("shape,step,wd", [((64,), 0, 0.0), ((33, 7), 5, 0.0),
                                           ((128, 16), 100, 0.01),
                                           ((70001,), 3, 0.0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_adam_vs_ref(shape, step, wd, dtype):
    from repro.kernels.fused_adam import fused_adam
    p = jnp.asarray(RNG.normal(size=shape), dtype)
    g = jnp.asarray(RNG.normal(size=shape), dtype)
    m = jnp.asarray(RNG.normal(size=shape) * 0.1, jnp.float32)
    v = jnp.asarray(np.abs(RNG.normal(size=shape)) * 0.1, jnp.float32)
    p2, m2, v2 = fused_adam(p, g, m, v, step, lr=1e-3, weight_decay=wd,
                            block=4096)
    pr, mr, vr = ref.fused_adam_ref(p, g, m, v, step, lr=1e-3,
                                    weight_decay=wd)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(p2, np.float32),
                               np.asarray(pr, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(vr), atol=1e-6)


def test_fused_adam_matches_optim_adam():
    """The kernel implements exactly repro.optim.adam's update rule."""
    from repro.kernels.fused_adam import fused_adam
    from repro.optim import adam
    from repro.optim.optimizer import apply_updates
    opt = adam(3e-3)
    params = {"w": jnp.asarray(RNG.normal(size=(31,)), jnp.float32)}
    grads = {"w": jnp.asarray(RNG.normal(size=(31,)), jnp.float32)}
    state = opt.init(params)
    upd, state2 = opt.update(grads, state, params, 7)
    want = apply_updates(params, upd)
    p2, m2, v2 = fused_adam(params["w"], grads["w"], state["m"]["w"],
                            state["v"]["w"], 7, lr=3e-3, block=64)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(want["w"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(state2["m"]["w"]),
                               atol=1e-7)
