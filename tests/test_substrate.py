"""Substrate tests: optimizers, schedules, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint, latest_step
from repro.core.protocol import EntityState, entity_step, init_entity
from repro.data.federated import FederatedDataset, sample_cohort
from repro.data.partition import dirichlet_partition, power_law_sizes
from repro.data.synthetic import SyntheticCharLMTask, SyntheticImageTask
from repro.optim import adam, clip_by_global_norm, sgd
from repro.optim.optimizer import apply_updates
from repro.optim.schedule import constant, cosine, exponential_decay


# ---------------------------------------------------------------- optim
def test_sgd_step_matches_formula():
    params = {"w": jnp.ones((3,))}
    grads = {"w": jnp.full((3,), 2.0)}
    opt = sgd(0.1)
    upd, _ = opt.update(grads, opt.init(params), params, 0)
    np.testing.assert_allclose(np.asarray(apply_updates(params, upd)["w"]),
                               np.ones(3) - 0.2, atol=1e-7)


def test_adam_converges_on_quadratic():
    opt = adam(0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_entity(params, opt)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        g = {"w": 2 * (state.params["w"] - target)}
        state = entity_step(state, g, opt)
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(target), atol=1e-2)


def test_adam_bias_correction_first_step():
    """First Adam step ≈ -lr * sign(g) regardless of gradient scale."""
    opt = adam(1e-3)
    for scale in (1e-4, 1.0, 1e4):
        params = {"w": jnp.zeros(())}
        upd, _ = opt.update({"w": jnp.asarray(scale)}, opt.init(params),
                            params, 0)
        np.testing.assert_allclose(float(upd["w"]), -1e-3, rtol=1e-3)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((3,), 4.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    total = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                               for x in jax.tree.leaves(clipped))))
    assert abs(total - 1.0) < 1e-5
    assert float(gn) > 1.0


def test_schedules_shapes():
    assert float(constant(0.1)(100)) == pytest.approx(0.1)
    cos = cosine(1.0, warmup=10, total=100)
    assert float(cos(0)) == pytest.approx(0.0)
    assert float(cos(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(cos(100)) == pytest.approx(0.1, abs=1e-3)
    exp = exponential_decay(1.0, 0.5, 10)
    assert float(exp(10)) == pytest.approx(0.5)


def test_cosine_edge_cases():
    # no warmup is fine: decay starts immediately at full lr
    cos0 = cosine(1.0, warmup=0, total=100)
    assert float(cos0(0)) == pytest.approx(1.0)
    assert float(cos0(100)) == pytest.approx(0.1, abs=1e-3)
    # past the horizon the schedule clamps at the floor, no rebound
    cos = cosine(1.0, warmup=10, total=100, final_frac=0.1)
    assert float(cos(100)) == float(cos(250)) == pytest.approx(0.1,
                                                               abs=1e-3)
    # regression: total <= warmup used to silently collapse the decay
    # window to one step (lr cliffed straight to the floor) — it must
    # be rejected at construction now
    with pytest.raises(ValueError, match="total"):
        cosine(1.0, warmup=100, total=100)
    with pytest.raises(ValueError, match="total"):
        cosine(1.0, warmup=100, total=50)
    with pytest.raises(ValueError, match="warmup"):
        cosine(1.0, warmup=-1, total=50)


# ----------------------------------------------------------------- data
def test_dirichlet_partition_covers_everything(rng):
    labels = rng.integers(0, 10, size=2000)
    parts = dirichlet_partition(labels, 20, alpha=0.5, rng=rng)
    assert len(parts) == 20
    assert all(len(p) >= 2 for p in parts)


def test_dirichlet_alpha_controls_skew(rng):
    labels = rng.integers(0, 10, size=20000)

    def skew(alpha):
        parts = dirichlet_partition(labels, 50, alpha=alpha,
                                    rng=np.random.default_rng(0))
        stds = []
        for p in parts:
            hist = np.bincount(labels[p], minlength=10) / max(1, len(p))
            stds.append(hist.std())
        return np.mean(stds)

    assert skew(0.1) > skew(1.0) > skew(np.inf) - 1e-9


def test_power_law_sizes(rng):
    sizes = power_law_sizes(100, 10_000, rng)
    assert sizes.min() >= 8
    assert sizes.max() > np.median(sizes) * 2  # heavy tail


def test_federated_split_is_sample_wise(rng):
    gen = SyntheticImageTask(n_clients=10, samples_per_client=30, seed=1)
    x, y, owner, idx = gen.build()
    fed = FederatedDataset.from_arrays(x, y, idx)
    assert fed.n_clients == 10
    for c in fed.clients:
        assert len(c.x_test) >= 1 and len(c.x_train) >= 2
    xs, ys = fed.test_arrays()
    assert len(xs) == sum(len(c.x_test) for c in fed.clients)


def test_cohort_sampling_rate(rng):
    cohort = sample_cohort(1000, 0.05, rng)
    assert len(cohort) == 50
    assert len(np.unique(cohort)) == 50


def test_charlm_task_builds(rng):
    gen = SyntheticCharLMTask(n_clients=4, samples_per_client=16, seed=0)
    x, y, owner, idx = gen.build()
    assert x.shape == (64, gen.seq_len)
    assert y.min() >= 0 and y.max() < gen.vocab


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)},
            "tup": (jnp.zeros(2), jnp.full((1,), 7.0))}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 5, tree, metadata={"note": "x"})
    save_checkpoint(d, 9, tree)
    assert latest_step(d) == 9
    restored, step = load_checkpoint(d, tree)
    assert step == 9
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.zeros(1)}
    for s in range(6):
        save_checkpoint(d, s, tree, keep=3)
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d))
    assert steps == [3, 4, 5]


def test_checkpoint_restores_entity_state(tmp_path):
    opt = adam(1e-3)
    st = init_entity({"w": jnp.ones((2, 2))}, opt)
    st = entity_step(st, {"w": jnp.ones((2, 2))}, opt)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, st)
    restored, _ = load_checkpoint(d, st)
    assert int(restored.step) == 1
    np.testing.assert_allclose(np.asarray(restored.params["w"]),
                               np.asarray(st.params["w"]))
