"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned archs: instantiate the REDUCED variant
(<=2 layers, d_model<=512, <=4 experts — zamba2 uses 4 mamba blocks to
exercise the shared-attention interleave), run one forward *and* one
CycleSL train round on CPU, assert output shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config, list_archs, smoke_config
from repro.core.cyclesl import CycleConfig, cyclesl_round
from repro.core.protocol import broadcast_entity, init_entity
from repro.core.split import make_transformer_task
from repro.launch.steps import make_whisper_task
from repro.models.encdec import EncDec
from repro.models.transformer import Transformer
from repro.optim import adam

ARCHS = list_archs()


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected, f"{arch}: {got} != {expected}"
    assert cfg.source


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_config_is_reduced(arch):
    cfg = smoke_config(arch)
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = smoke_config(arch)
    B, S = 2, 16
    key = jax.random.PRNGKey(0)
    if cfg.family == "audio":
        params = EncDec.init(key, cfg)
        frames = jax.random.normal(key, (B, 8, cfg.enc_d_model)) * 0.1
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        logits = EncDec.forward(params, cfg, frames, toks)
    else:
        params = Transformer.init(key, cfg)
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        pe = (jnp.ones((B, cfg.n_patch_tokens, cfg.d_model)) * 0.01
              if cfg.family == "vlm" else None)
        logits, _ = Transformer.forward(params, cfg, toks, pe)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_cyclesl_train_round(arch):
    """One CycleSL round on the reduced arch: loss finite, params move."""
    cfg = smoke_config(arch)
    C, b, S = 2, 2, 16
    key = jax.random.PRNGKey(0)
    opt = adam(1e-3)
    if cfg.family == "audio":
        task = make_whisper_task(cfg)
        xs = {"frames": jax.random.normal(key, (C, b, 8, cfg.enc_d_model)) * 0.1}
        ys = {"tokens": jax.random.randint(key, (C, b, S), 0, cfg.vocab),
              "labels": jax.random.randint(key, (C, b, S), 0, cfg.vocab)}
    else:
        task = make_transformer_task(cfg)
        xs = {"tokens": jax.random.randint(key, (C, b, S), 0, cfg.vocab)}
        if cfg.family == "vlm":
            xs["patch_embeds"] = jnp.ones(
                (C, b, cfg.n_patch_tokens, cfg.d_model), jnp.float32) * 0.01
        ys = jax.random.randint(jax.random.PRNGKey(1), (C, b, S), 0, cfg.vocab)
    server = init_entity(task.init_server(jax.random.PRNGKey(2)), opt)
    clients = broadcast_entity(
        init_entity(task.init_client(jax.random.PRNGKey(3)), opt), C)
    server2, clients2, metrics = cyclesl_round(
        task, server, clients, opt, opt, xs, ys, jax.random.PRNGKey(4),
        CycleConfig(server_epochs=1))
    assert bool(jnp.isfinite(metrics["server_loss"]))
    assert bool(jnp.isfinite(metrics["feat_grad_norm_mean"]))
    # server and clients both moved
    moved_s = any(bool(jnp.any(a != b)) for a, b in zip(
        jax.tree.leaves(server.params), jax.tree.leaves(server2.params)))
    moved_c = any(bool(jnp.any(a != b)) for a, b in zip(
        jax.tree.leaves(clients.params), jax.tree.leaves(clients2.params)))
    assert moved_s and moved_c


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "whisper-base"])
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    B = 2
    params = Transformer.init(jax.random.PRNGKey(0), cfg)
    state = Transformer.init_decode_state(cfg, B, seq_len=8)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, state2 = Transformer.decode_step(params, cfg, tok, state)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(state2["pos"]) == 1
