"""Padded-cohort execution: the compile-once contract and its goldens.

Three guarantees, per registered algorithm:

1. **Padded == unpadded, bit-for-bit.**  A round executed at capacity
   C_max > live cohort (sentinel ids, zeroed batches, attendance mask)
   produces exactly the same TrainState and metrics as the same round
   executed at the live size.  For the cycle algorithms both sides run
   the mask-aware path (the masked resample plan is shape-invariant by
   construction); the plain-mean algorithms are additionally compared
   against the truly unmasked legacy call.
2. **One trace per (algo, config).**  Rounds with varying live cohort
   sizes (fixed capacity, varying mask) never retrace the jitted round.
3. **The fused Adam path is the jnp Adam.**  adam(fused=True) (Pallas,
   interpret mode on CPU) matches the tree-map reference through
   entity_step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PROGRAMS, build_algorithm, get_program
from repro.api.phases import ServerUpdate
from repro.core.cyclesl import CycleConfig
from repro.core.feature_store import masked_resample_plan
from repro.core.protocol import init_entity, entity_step
from repro.core.split import make_stage_task
from repro.data.federated import sample_cohort
from repro.models.cnn import mlp
from repro.optim import adam

C, B, PAD = 4, 8, 3


@pytest.fixture(scope="module")
def setup():
    task = make_stage_task(mlp(8, [16], 4), cut=1, kind="xent")
    rng = np.random.default_rng(0)
    w = rng.normal(size=(8, 4))
    xs = np.stack([rng.normal(size=(B, 8))
                   for _ in range(C)]).astype(np.float32)
    ys = np.argmax(xs @ w, axis=-1)
    return task, jnp.asarray(xs), jnp.asarray(ys)


def _padded(xs, ys):
    cohort = jnp.arange(C)
    xs_p = jnp.concatenate([xs, jnp.zeros((PAD,) + xs.shape[1:], xs.dtype)])
    ys_p = jnp.concatenate([ys, jnp.zeros((PAD,) + ys.shape[1:], ys.dtype)])
    cohort_p = jnp.concatenate([cohort, jnp.full((PAD,), C, cohort.dtype)])
    mask_p = jnp.concatenate([jnp.ones(C, jnp.float32),
                              jnp.zeros(PAD, jnp.float32)])
    return cohort, cohort_p, xs_p, ys_p, mask_p


def _assert_trees_equal(a, b, msg, exact=True):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=msg)
        else:
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=1e-6, atol=1e-8, err_msg=msg)


def _is_cycle(name):
    return any(getattr(p, "mode", None) == "cycle"
               for p in get_program(name).phases)


@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_padded_round_matches_unpadded_bit_for_bit(name, setup):
    """The tentpole golden: executing at capacity C+PAD with a mask is
    bit-identical to executing at the live size C, for every algorithm,
    over multiple rounds (params, optimizer state, and metrics)."""
    task, xs, ys = setup
    cohort, cohort_p, xs_p, ys_p, mask_p = _padded(xs, ys)
    mask_live = jnp.ones(C, jnp.float32)
    opt = adam(5e-3)
    algo = build_algorithm(get_program(name), task, opt, opt,
                           CycleConfig(server_epochs=2))
    s_live = algo.init(jax.random.PRNGKey(0), n_clients=C)
    s_pad = algo.init(jax.random.PRNGKey(0), n_clients=C)
    for r in range(3):
        k = jax.random.PRNGKey(r)
        s_live, m_live = algo.round(s_live, cohort, xs, ys, k, mask_live)
        s_pad, m_pad = algo.round(s_pad, cohort_p, xs_p, ys_p, k, mask_p)
        for key in m_live:
            np.testing.assert_array_equal(
                np.asarray(m_live[key]), np.asarray(m_pad[key]),
                err_msg=f"{name} round {r}: metric {key}")
    _assert_trees_equal(s_live.server, s_pad.server, f"{name}: server state")
    cl_live = s_live.clients if s_live.clients is not None \
        else s_live.client_global
    cl_pad = s_pad.clients if s_pad.clients is not None \
        else s_pad.client_global
    _assert_trees_equal(cl_live, cl_pad, f"{name}: client state")


@pytest.mark.parametrize("name",
                         sorted(n for n in PROGRAMS if not _is_cycle(n)))
def test_masked_all_ones_matches_legacy_unmasked(name, setup):
    """For every non-cycle algorithm the mask-aware path with an
    all-ones mask reproduces the legacy unmasked call (bit-for-bit,
    except ssl where the extra selects reorder XLA fusion at ~1e-9).
    The cycle algorithms are excluded by design: their masked server
    resample plan is a different — shape-invariant — random stream."""
    task, xs, ys = setup
    cohort = jnp.arange(C)
    opt = adam(5e-3)
    algo = build_algorithm(get_program(name), task, opt, opt,
                           CycleConfig(server_epochs=2))
    s_a = algo.init(jax.random.PRNGKey(0), n_clients=C)
    s_b = algo.init(jax.random.PRNGKey(0), n_clients=C)
    for r in range(3):
        k = jax.random.PRNGKey(r)
        s_a, _ = algo.round(s_a, cohort, xs, ys, k)
        s_b, _ = algo.round(s_b, cohort, xs, ys, k,
                            jnp.ones(C, jnp.float32))
    _assert_trees_equal(s_a.server.params, s_b.server.params,
                        f"{name}: server params", exact=(name != "ssl"))


@pytest.mark.parametrize("name", ["cyclesfl", "psl", "cyclessl"])
def test_round_traces_exactly_once_across_varying_cohorts(name, setup):
    """The compile-stability acceptance: with fixed padded shapes and a
    varying attendance mask, the round function is traced exactly once
    no matter how the live cohort size changes round to round."""
    task, xs, ys = setup
    _, cohort_p, xs_p, ys_p, _ = _padded(xs, ys)
    opt = adam(5e-3)
    algo = build_algorithm(get_program(name), task, opt, opt,
                           CycleConfig(server_epochs=1))
    state = algo.init(jax.random.PRNGKey(0), n_clients=C)
    cap = C + PAD
    for r in range(6):
        live = 2 + r % 3                       # live cohort size varies
        mask = jnp.asarray((np.arange(cap) < live).astype(np.float32))
        state, m = algo.round(state, cohort_p, xs_p, ys_p,
                              jax.random.PRNGKey(r), mask)
        assert np.isfinite(float(m["server_loss"]))
    assert algo.trace_count == 1, (
        f"{name}: round retraced {algo.trace_count} times across varying "
        "live cohort sizes — compile-once contract broken")


def test_masked_resample_plan_is_capacity_invariant():
    """The live-row sequence the plan yields must not depend on how much
    padding sits behind the live rows — the property the padded-vs-
    unpadded goldens rest on."""
    key = jax.random.PRNGKey(7)
    n_live, batch, epochs = 20, 5, 3
    for cap in (n_live, n_live + 7, n_live + 40):
        valid = jnp.concatenate([jnp.ones(n_live), jnp.zeros(cap - n_live)])
        plan, ok = masked_resample_plan(key, valid, epochs, batch)
        live_steps = n_live // batch
        assert bool(jnp.all(ok[:, :live_steps]))
        assert bool(jnp.all(~ok[:, live_steps:]))
        got = np.asarray(plan[:, :live_steps])
        if cap == n_live:
            want = got
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"capacity {cap}")
        # valid steps index live rows only, each epoch a permutation slice
        assert got.max() < n_live
        for e in range(epochs):
            flat = got[e].reshape(-1)
            assert len(set(flat.tolist())) == len(flat)


def test_sample_cohort_variable_attendance():
    rng = np.random.default_rng(0)
    sizes = {len(sample_cohort(100, 0.1, rng, min_cohort=2, variable=True,
                               max_cohort=15)) for _ in range(200)}
    assert len(sizes) > 1                      # sizes actually vary
    assert min(sizes) >= 2 and max(sizes) <= 15
    # deterministic protocol unchanged
    rng = np.random.default_rng(0)
    assert len(sample_cohort(100, 0.05, rng)) == 5


def test_fused_adam_matches_reference_through_entity_step():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(33, 7)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
    grads = jax.tree.map(
        lambda p: jnp.asarray(rng.normal(size=p.shape), jnp.float32), params)
    ref = adam(1e-3, weight_decay=0.01)
    fus = adam(1e-3, weight_decay=0.01, fused=True)   # Pallas (interpret)
    assert fus.apply is not None and ref.apply is None  # CPU auto-gates off
    e_r, e_f = init_entity(params, ref), init_entity(params, fus)
    for _ in range(3):
        e_r = entity_step(e_r, grads, ref)
        e_f = entity_step(e_f, grads, fus)
    assert int(e_r.step) == int(e_f.step) == 3
    for a, b in zip(jax.tree.leaves(e_r.params), jax.tree.leaves(e_f.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    for a, b in zip(jax.tree.leaves(e_r.opt_state),
                    jax.tree.leaves(e_f.opt_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_fused_adam_rejects_schedules():
    with pytest.raises(ValueError):
        adam(lambda s: 1e-3, fused=True)


def test_engine_capacity_matches_deterministic_sampler():
    """Deterministic attendance must never produce a dead padded slot:
    capacity == round(attendance * N) == the sampler's draw."""
    from repro.api import Engine, ExperimentConfig
    cfg = ExperimentConfig(algo="cyclesfl", task="image", rounds=1,
                           n_clients=20, attendance=0.21, width=4, seed=0)
    eng = Engine(cfg, log=lambda *a, **k: None)
    assert eng.cohort_capacity == 4          # round(4.2), not ceil
    _, _, _, mask = eng.sample_round(np.random.default_rng(0))
    assert float(mask.sum()) == eng.cohort_capacity
    # variable attendance bounds the Binomial with the ceil
    from dataclasses import replace
    eng = Engine(replace(cfg, variable_attendance=True),
                 log=lambda *a, **k: None)
    assert eng.cohort_capacity == 5


def test_engine_rejects_server_batch_exceeding_min_live_pool():
    """A static server batch larger than the smallest possible live
    pool would silently skip server training in sparse rounds."""
    from repro.api import Engine, ExperimentConfig
    cfg = ExperimentConfig(algo="cyclesfl", task="image", rounds=1,
                           n_clients=24, attendance=0.25, batch=8,
                           min_cohort=2, width=4, seed=0,
                           variable_attendance=True,
                           cycle=CycleConfig(server_batch=32))
    with pytest.raises(ValueError, match="server_batch"):
        Engine(cfg, log=lambda *a, **k: None)


def test_cycle_variants_share_masked_plan_semantics(setup):
    """A padded cycle round with server_steps capped still matches its
    live-size reference (the step-validity mask composes with the
    server_steps truncation)."""
    task, xs, ys = setup
    cohort, cohort_p, xs_p, ys_p, mask_p = _padded(xs, ys)
    opt = adam(5e-3)
    algo = build_algorithm(get_program("cyclesfl"), task, opt, opt,
                           CycleConfig(server_epochs=3, server_steps=2))
    s_live = algo.init(jax.random.PRNGKey(0), n_clients=C)
    s_pad = algo.init(jax.random.PRNGKey(0), n_clients=C)
    k = jax.random.PRNGKey(0)
    s_live, m_live = algo.round(s_live, cohort, xs, ys, k,
                                jnp.ones(C, jnp.float32))
    s_pad, m_pad = algo.round(s_pad, cohort_p, xs_p, ys_p, k, mask_p)
    np.testing.assert_array_equal(np.asarray(m_live["server_loss"]),
                                  np.asarray(m_pad["server_loss"]))
    _assert_trees_equal(s_live.server.params, s_pad.server.params,
                        "server_steps cap under padding")
