"""Mesh-native execution: sharding invariance, trace pins, resume, and
the kernel-dispatched resample gather.

The tentpole contract (ISSUE 3): sharding flows from config to kernel
without touching numerics — a 1-device mesh is bit-for-bit the
unsharded Engine, a forced multi-device host mesh agrees to float
reduction noise and still traces ONCE, and the FeatureStore resample
gather dispatches through ``kernels.ops.feature_resample``.  The full
per-algorithm multi-device comparison runs in a subprocess
(``repro.launch.meshcheck``) because the host device count binds at
jax initialization.
"""
import json
import os
import subprocess
import sys
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Engine, ExperimentConfig, build_algorithm, get_program
from repro.core.feature_store import FeatureStore, gather_batch
from repro.launch.meshcheck import C, _drive, _task_and_data
from repro.optim import adam
from repro.sharding.specs import train_state_shardings


@pytest.fixture(scope="module")
def setup():
    # the exact task/data/drive protocol the subprocess meshcheck runs —
    # shared so the in-process goldens and the 8-device sweep can't drift
    return _task_and_data()


def _mesh1():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


def _assert_equal(a_state, a_rows, b_state, b_rows, msg):
    for ra, rb in zip(a_rows, b_rows):
        for k in ra:
            np.testing.assert_array_equal(ra[k], rb[k],
                                          err_msg=f"{msg}: metric {k}")
    for la, lb in zip(jax.tree.leaves(a_state), jax.tree.leaves(b_state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{msg}: state")


# ------------------------------------------------------------ invariance
@pytest.mark.parametrize("name", ["cyclesfl", "psl", "sglr", "ssl"])
def test_one_device_mesh_is_bit_for_bit_unsharded(name, setup):
    """Sharding constraints pin layout, never values: the full mesh path
    (placed state, committed inputs, constrained phases, pinned output
    shardings) on ONE device reproduces the unsharded round exactly.
    The remaining algorithms are covered by the subprocess meshcheck."""
    task, xs, ys = setup
    base_state, base_rows, _ = _drive(name, task, xs, ys)
    s1, r1, _ = _drive(name, task, xs, ys, mesh=_mesh1())
    _assert_equal(base_state, base_rows, s1, r1, name)


@pytest.mark.parametrize("name", ["cyclesfl", "psl"])
def test_sharded_round_traces_exactly_once(name, setup):
    """Compile-once per (algo, config, mesh): the mesh path with pinned
    output shardings never retraces across varying live cohort sizes."""
    task, xs, ys = setup
    _, _, traces = _drive(name, task, xs, ys, mesh=_mesh1(), rounds=5)
    assert traces == 1, (f"{name}: sharded round traced {traces} times — "
                         "compile-once per (algo, config, mesh) broken")


def test_engine_mesh_matches_unsharded_engine():
    """Engine-level golden: cfg.mesh_shape=(1,1) drives the whole
    mesh-native stack (mesh build, NamedSharding placement, committed
    inputs, out_shardings) and must be bit-for-bit the classic path."""
    class Rec:
        def __init__(self):
            self.rows, self.state = [], None

        def on_round(self, engine, rnd, state, metrics):
            self.rows.append({k: np.asarray(v) for k, v in metrics.items()})
            self.state = state

    cfg = ExperimentConfig(algo="cyclesfl", task="image", rounds=3,
                           n_clients=8, attendance=0.5, batch=4, width=4,
                           eval_every=3, seed=0)
    r0, r1 = Rec(), Rec()
    Engine(cfg, callbacks=(r0,), log=lambda *a, **k: None).run()
    eng = Engine(replace(cfg, mesh_shape=(1, 1)), callbacks=(r1,),
                 log=lambda *a, **k: None)
    eng.run()
    assert eng.mesh is not None and eng.state_shardings is not None
    _assert_equal(r0.state, r0.rows, r1.state, r1.rows, "engine mesh")


def test_meshcheck_all_algorithms_on_forced_8_device_mesh():
    """The multi-device invariance sweep: every registered algorithm,
    unsharded vs 1-device mesh (exact) vs an 8-device CPU host mesh
    (reduction-noise tolerance), one trace each.  Subprocess because
    XLA_FLAGS must bind before jax initializes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath("src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.meshcheck", "--devices", "8"],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, (
        f"meshcheck failed\nstdout: {proc.stdout[-3000:]}\n"
        f"stderr: {proc.stderr[-3000:]}")
    report = json.loads(proc.stdout)
    assert report["ok"] and report["devices"] == 8
    for name, rec in report["algos"].items():
        assert rec["exact_1dev_diff"] == 0.0, name
        assert rec["ndev_traces"] == 1, name


# ------------------------------------------------------------- config
def test_mesh_config_json_roundtrip():
    cfg = ExperimentConfig(algo="cyclesfl", mesh_shape=(8, 1),
                           mesh_axes=("data", "model"),
                           shard_cohort=False, resume=True)
    back = ExperimentConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back == cfg
    assert isinstance(back.mesh_shape, tuple)
    assert isinstance(back.mesh_axes, tuple)


def test_from_dict_tolerates_legacy_batch_constraint_key():
    """Pre-mesh config JSONs carry cycle.batch_constraint=null (the
    removed callable hook); they must still load."""
    cfg = ExperimentConfig(algo="sglr", rounds=3)
    d = json.loads(json.dumps(cfg.to_dict()))
    d["cycle"]["batch_constraint"] = None
    assert ExperimentConfig.from_dict(d) == cfg


def test_run_places_caller_provided_state_on_mesh():
    """Engine.run(state=...) must commit the state to the mesh placement
    or round 1 would retrace against round 0's pinned out_shardings."""
    cfg = ExperimentConfig(algo="psl", task="image", rounds=3, n_clients=8,
                           attendance=0.5, batch=4, width=4, eval_every=3,
                           seed=0, mesh_shape=(1, 1))
    eng = Engine(cfg, log=lambda *a, **k: None)
    raw = eng.algo.init(jax.random.PRNGKey(cfg.seed), 8)   # unplaced
    eng.run(state=raw)
    assert eng.algo.trace_count == 1


def test_mesh_config_validates_shape_axes():
    with pytest.raises(ValueError, match="equal length"):
        ExperimentConfig(mesh_shape=(2, 2, 2)).validate()
    with pytest.raises(ValueError, match="positive"):
        ExperimentConfig(mesh_shape=(0, 1)).validate()


def test_train_state_shardings_roles(setup):
    """Client stack leading cohort dim takes the batch axes; server and
    client_global weights follow the path rules (replicated for mlp)."""
    task, _, _ = setup
    opt = adam(1e-3)
    mesh = _mesh1()
    for name, cohort_dim_expected in (("psl", "data"), ("cyclesfl", None)):
        algo = build_algorithm(get_program(name), task, opt, opt)
        a_state = jax.eval_shape(
            lambda a=algo: a.init(jax.random.PRNGKey(0), C))
        sh = train_state_shardings(a_state, mesh)
        server_leaf = jax.tree.leaves(sh.server)[0]
        assert all(a is None for a in server_leaf.spec)
        if name == "psl":
            assert sh.client_global is None
            leaf = jax.tree.leaves(sh.clients)[0]
            assert leaf.spec[0] == cohort_dim_expected
        else:
            assert sh.clients is None
            assert jax.tree.leaves(sh.client_global)[0] is not None
        # shard_cohort=False keeps the stack replicated
        sh_off = train_state_shardings(a_state, mesh, shard_cohort=False)
        if sh_off.clients is not None:
            assert jax.tree.leaves(sh_off.clients)[0].spec[0] is None


# ------------------------------------------------- shard-local resample
def _n_mesh():
    """The widest (N, 1) mesh this process can build: 8 under the CI
    devices8/kernels legs, 1 on the default single-CPU-device run (where
    the 8-device case is covered by the subprocess golden below)."""
    n = 8 if jax.device_count() >= 8 else 1
    return jax.make_mesh((n, 1), ("data", "model"),
                         devices=jax.devices()[:n])


@pytest.mark.kernels
@pytest.mark.parametrize("use_kernel", [False, True])
def test_shard_local_gather_matches_gspmd_gather(use_kernel):
    """Tentpole contract: the shard_map-wrapped resample (per-shard
    index translation + masked cross-shard fixup) is bit-for-bit the
    plain gather — multi-dim features, pytree labels, both the jnp and
    the (interpret) Pallas per-shard gather, and both the
    reduce-scatter (M divides shards) and all-reduce fixups."""
    from repro.core.feature_store import shard_local_gather
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _n_mesh()
    rng = np.random.default_rng(7)
    feats = jnp.asarray(rng.normal(size=(48, 4, 6)), jnp.float32)
    labels = {"y": jnp.asarray(rng.integers(0, 9, size=(48,)), jnp.int32),
              "aux": jnp.asarray(rng.normal(size=(48, 3)), jnp.float32)}
    place = lambda l: jax.device_put(
        l, NamedSharding(mesh, P("data", *([None] * (l.ndim - 1)))))
    store = FeatureStore(place(feats), jax.tree.map(place, labels))
    for m in (16, 13):          # 16 divides 8 shards (scatter), 13 not
        idx = jnp.asarray(rng.integers(0, 48, size=m), jnp.int32)
        f_ref, y_ref = gather_batch(store, idx, use_kernel=False)
        f, y = shard_local_gather(store, idx, mesh, use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(f), np.asarray(f_ref))
        for k in y_ref:
            np.testing.assert_array_equal(np.asarray(y[k]),
                                          np.asarray(y_ref[k]))


@pytest.mark.kernels
def test_shard_local_round_is_bit_for_bit_and_traces_once():
    """CycleConfig.shard_local_resample on a mesh must not change a bit
    of any round output, and the shard_map wrapper must not retrace
    across varying live cohort sizes (compile-once holds)."""
    task, xs, ys = _task_and_data()
    mesh = _n_mesh()
    base_state, base_rows, _ = _drive("cyclesfl", task, xs, ys, mesh=mesh,
                                      rounds=5)
    s, r, traces = _drive("cyclesfl", task, xs, ys, mesh=mesh, rounds=5,
                          shard_local=True)
    _assert_equal(base_state, base_rows, s, r, "shard-local cyclesfl")
    assert traces == 1, (f"shard-local round traced {traces} times — the "
                         "shard_map wrapper broke compile-once")


@pytest.mark.kernels
def test_meshcheck_shard_local_golden_all_algorithms_8_devices():
    """The acceptance golden: every registered algorithm, monolithic AND
    pipelined, on a 1-device and a forced 8-device mesh — shard-local
    resample bit-for-bit the GSPMD path, trace budget held.  Subprocess
    because XLA_FLAGS must bind before jax initializes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath("src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.meshcheck", "--devices", "8",
         "--shard-local"],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, (
        f"shard-local meshcheck failed\nstdout: {proc.stdout[-3000:]}\n"
        f"stderr: {proc.stderr[-3000:]}")
    report = json.loads(proc.stdout)
    assert report["ok"] and report["mode"] == "shard_local"
    for name, rec in report["algos"].items():
        assert rec["ok"], (name, rec)
        assert rec["8dev"]["diff"] == 0.0, name


def test_inner_loop_resample_use_kernel_override_is_threaded():
    """Satellite fix: CycleConfig.resample_use_kernel reaches the
    gather inside server_inner_loop.apply_step (it used to be dropped —
    gather_batch was always called with defaults), and the forced
    interpret-kernel path is bit-for-bit the jnp path."""
    from repro.api import build_algorithm, get_program
    from repro.core.cyclesl import CycleConfig
    from repro.optim import adam
    task, xs, ys = _task_and_data()
    opt = adam(5e-3)

    def drive(use_kernel):
        algo = build_algorithm(
            get_program("cyclesfl"), task, opt, opt,
            CycleConfig(server_epochs=2, resample_use_kernel=use_kernel))
        state = algo.init(jax.random.PRNGKey(0), n_clients=C)
        state, mets = algo.round(state, jnp.arange(C), xs, ys,
                                 jax.random.PRNGKey(0))
        return state, mets

    s_jnp, m_jnp = drive(False)
    s_krn, m_krn = drive(True)
    _assert_equal(s_jnp, [{k: np.asarray(v) for k, v in m_jnp.items()}],
                  s_krn, [{k: np.asarray(v) for k, v in m_krn.items()}],
                  "resample_use_kernel")


# ----------------------------------------------------- resample dispatch
def test_gather_batch_kernel_path_matches_jnp_take():
    """Satellite: the FeatureStore resample gather dispatched through
    kernels.ops.feature_resample (Pallas, interpret on CPU) is the exact
    jnp.take gather — for multi-dim features and pytree labels."""
    rng = np.random.default_rng(3)
    feats = jnp.asarray(rng.normal(size=(24, 4, 6)), jnp.float32)
    labels = {"y": jnp.asarray(rng.integers(0, 9, size=(24,)), jnp.int32),
              "aux": jnp.asarray(rng.normal(size=(24, 3)), jnp.float32)}
    store = FeatureStore(feats, labels)
    idx = jnp.asarray(rng.permutation(24)[:16], jnp.int32)
    f_ref, y_ref = gather_batch(store, idx, use_kernel=False)
    f_k, y_k = gather_batch(store, idx, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_k))
    for k in y_ref:
        np.testing.assert_array_equal(np.asarray(y_ref[k]),
                                      np.asarray(y_k[k]))


def test_gather_batch_auto_gate_off_tpu():
    """Backend gate mirrors fused_adam: off-TPU the default path is the
    XLA gather (the kernel is TPU-targeted)."""
    assert jax.default_backend() != "tpu"   # this container is CPU-only
    store = FeatureStore(jnp.ones((4, 2)), jnp.zeros((4,)))
    f, _ = gather_batch(store, jnp.asarray([1, 0]))
    assert f.shape == (2, 2)                # jnp path, no kernel invoked


# --------------------------------------------------------------- resume
def test_engine_resume_matches_uninterrupted_run(tmp_path):
    """Satellite: a run checkpointed at round 4 and resumed for rounds
    5..6 lands bit-for-bit on the uninterrupted 6-round run — state,
    final eval, and cadence all aligned (cohort stream replayed)."""
    base = ExperimentConfig(algo="cyclesfl", task="image", rounds=6,
                            n_clients=8, attendance=0.5, batch=4, width=4,
                            eval_every=2, seed=0)

    class Rec:
        def __init__(self):
            self.state = None

        def on_round(self, engine, rnd, state, metrics):
            self.state = state

    # uninterrupted reference
    ra = Rec()
    full = Engine(replace(base, ckpt_dir=str(tmp_path / "a")),
                  callbacks=(ra,), log=lambda *a, **k: None).run()
    # interrupted at round 4 (ckpts land at eval rounds 2, 4)...
    dir_b = str(tmp_path / "b")
    Engine(replace(base, rounds=4, ckpt_dir=dir_b),
           log=lambda *a, **k: None).run()
    # ...then resumed to 6
    rb = Rec()
    resumed = Engine(replace(base, ckpt_dir=dir_b, resume=True),
                     callbacks=(rb,), log=lambda *a, **k: None).run()
    assert resumed["resumed_from_round"] == 4
    for la, lb in zip(jax.tree.leaves(ra.state), jax.tree.leaves(rb.state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # eval cadence aligned: the resumed history covers rounds 6 only,
    # and its entries equal the reference's tail
    tail = [h for h in full["history"] if h["round"] > 4]
    assert [h["round"] for h in resumed["history"]] == \
        [h["round"] for h in tail]
    for got, want in zip(resumed["history"], tail):
        assert got["test_loss"] == want["test_loss"]


def test_engine_resume_noop_without_checkpoints(tmp_path):
    """resume=True with an empty ckpt_dir starts from scratch."""
    cfg = ExperimentConfig(algo="psl", task="image", rounds=2, n_clients=8,
                           attendance=0.5, batch=4, width=4, eval_every=2,
                           seed=0, ckpt_dir=str(tmp_path / "empty"),
                           resume=True)
    res = Engine(cfg, log=lambda *a, **k: None).run()
    assert "resumed_from_round" not in res
    assert len(res["history"]) == 1
