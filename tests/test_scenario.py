"""repro.scenario: churn streams, the null-scenario equivalence golden,
the compile-once-under-churn contract, and the population simulator.

The load-bearing guarantees:

1. **Null scenario == scenario-free, bit-for-bit, every algorithm.**
   ``kind='none'`` builds no stream; a zero-churn ``uniform`` stream is
   also structurally inert (``weights=None``, no event draws), so both
   must reproduce the scenario-free Engine's history exactly.
2. **Churn is data, not shapes.**  Dropout/straggler events ride the
   compile-once attendance mask — one trace per (algo, config) no
   matter how the live cohort varies round to round.
3. **The server_batch guard fires** under variable attendance AND under
   scenario churn (both can shrink the live feature pool below a static
   server batch).
4. **Configs round-trip** through to_dict/from_dict and the flag parser.
5. **The population simulator scales by cohort, not fleet**: a run over
   a 100k-virtual-client federation materializes only the clients that
   attended.
"""
import argparse
from dataclasses import replace

import numpy as np
import pytest

from repro.api import PROGRAMS, Engine, ExperimentConfig
from repro.core.cyclesl import CycleConfig
from repro.core.split import make_stage_task
from repro.data.federated import FederatedDataset, sample_cohort
from repro.models.cnn import mlp
from repro.scenario.profiles import (STREAMS, ScenarioConfig,
                                     build_profile_stream, scenario_kinds)

N, ROUNDS = 24, 3


def _fed(n=N, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n * 12, 8)).astype(np.float32)
    w = rng.normal(size=(8, 4))
    y = np.argmax(x @ w, axis=-1)
    idx = np.arange(len(x)).reshape(n, -1)
    return FederatedDataset.from_arrays(x, y, list(idx), seed=seed)


@pytest.fixture(scope="module")
def setup():
    return make_stage_task(mlp(8, [8], 4), cut=1, kind="xent"), _fed()


def _cfg(**kw):
    base = dict(algo="cyclesfl", rounds=ROUNDS, n_clients=N, attendance=0.25,
                min_cohort=2, batch=4, width=8, cut=1, seed=0,
                eval_every=ROUNDS)
    base.update(kw)
    return ExperimentConfig(**base)


def _run(cfg, task, fed):
    res = Engine(cfg, task=task, fed=fed, metric_key="accuracy",
                 log=lambda *a, **k: None).run()
    # wall-clock differs run to run; everything else must not
    res["history"] = [{k: v for k, v in row.items() if k != "elapsed_s"}
                      for row in res["history"]]
    return res


# ------------------------------------------------- null-scenario golden
@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_null_scenario_bit_for_bit(name, setup):
    """kind='none' and a zero-churn uniform stream both reproduce the
    scenario-free run exactly, for every registered algorithm."""
    task, fed = setup
    base = _cfg(algo=name)
    r0 = _run(base, task, fed)
    r1 = _run(replace(base, scenario=ScenarioConfig(kind="none")), task, fed)
    r2 = _run(replace(base, scenario=ScenarioConfig(kind="uniform")),
              task, fed)
    assert r0["history"] == r1["history"], name
    assert r0["history"] == r2["history"], name


def test_null_scenario_builds_no_stream():
    assert build_profile_stream(ScenarioConfig(), 10, 0) is None
    assert build_profile_stream(ScenarioConfig(kind="uniform"), 10, 0) \
        .weights(0) is None


# --------------------------------------------- churn: one trace, masked
@pytest.mark.parametrize("kind", sorted(STREAMS))
def test_churn_compiles_once(kind, setup):
    """Varying per-round drops/lags (and, for diurnal, weighted cohort
    draws) never retrace the jitted round."""
    task, fed = setup
    cfg = _cfg(variable_attendance=True,
               scenario=ScenarioConfig(kind=kind, dropout=0.3,
                                       straggler=1.0))
    eng = Engine(cfg, task=task, fed=fed, metric_key="accuracy",
                 log=lambda *a, **k: None)
    res = eng.run()
    assert eng.algo.trace_count == 1
    tel = res["telemetry"]
    assert len(tel["per_round"]) == ROUNDS
    assert tel["dropped_total"] > 0                    # churn actually bit
    assert all(r["live"] >= 1 for r in tel["per_round"])
    assert all(r["live"] + r["dropped"] == r["cohort"]
               for r in tel["per_round"])
    assert tel["max_realized_lag"] == 0                # sequential schedule


def test_churn_with_pipelined_async(setup):
    """Stragglers under the async pipeline: realized lag is capped at
    the schedule's one-round-stale snapshot, drawn lag is unbounded."""
    task, fed = setup
    cfg = _cfg(pipeline_depth=1, pipeline_staleness="async",
               scenario=ScenarioConfig(kind="pareto-straggler",
                                       straggler=2.0, staleness_bound=2))
    eng = Engine(cfg, task=task, fed=fed, metric_key="accuracy",
                 log=lambda *a, **k: None)
    res = eng.run()
    tel = res["telemetry"]
    assert 0 <= tel["max_realized_lag"] <= 1
    assert eng.pipeline.extract_traces == 1
    assert eng.pipeline.tail_traces == 1


def test_dropped_slots_zero_the_mask(setup):
    """sample_round under churn: every dropped LIVE slot reads 0 in the
    attendance mask while keeping its real client id (the commit path
    then writes its entity back unchanged)."""
    task, fed = setup
    cfg = _cfg(scenario=ScenarioConfig(kind="uniform", dropout=0.5))
    eng = Engine(cfg, task=task, fed=fed, metric_key="accuracy",
                 log=lambda *a, **k: None)
    rng = np.random.default_rng(cfg.seed + 1)
    saw_drop = False
    for _ in range(6):
        cohort, xs, ys, mask = eng.sample_round(rng)
        row = eng._telemetry[-1]
        mask = np.asarray(mask)
        cohort = np.asarray(cohort)
        live = row["cohort"]
        assert int(mask[:live].sum()) == row["live"]
        assert (cohort[:live] < N).all()               # real ids, not sentinel
        assert mask[:live].sum() >= min(cfg.min_cohort, live)
        saw_drop |= row["dropped"] > 0
    assert saw_drop


def test_diurnal_weights_bias_cohorts():
    """Weighted sampling draws high-availability clients more often."""
    sc = ScenarioConfig(kind="diurnal-churn", dropout=0.1, amplitude=0.9)
    stream = build_profile_stream(sc, 200, seed=3)
    w = stream.weights(0)
    assert w.shape == (200,) and abs(w.sum() - 1.0) < 1e-9
    rng = np.random.default_rng(0)
    counts = np.zeros(200)
    for _ in range(300):
        counts[sample_cohort(200, 0.1, rng, weights=w)] += 1
    hi, lo = np.argsort(w)[-50:], np.argsort(w)[:50]
    assert counts[hi].mean() > counts[lo].mean()


# ----------------------------------------------------- guard regressions
def test_server_batch_guard_variable_attendance(setup):
    """The pre-existing guard: variable attendance + a static server
    batch larger than the smallest possible live pool must raise."""
    task, fed = setup
    cfg = _cfg(variable_attendance=True,
               cycle=CycleConfig(server_batch=64))
    with pytest.raises(ValueError, match="server_batch"):
        Engine(cfg, task=task, fed=fed, log=lambda *a, **k: None)


def test_server_batch_guard_scenario_churn(setup):
    """Scenario churn can shrink the live pool even at FIXED attendance,
    so the same guard must fire for a churny scenario."""
    task, fed = setup
    cfg = _cfg(scenario=ScenarioConfig(kind="uniform", dropout=0.2),
               cycle=CycleConfig(server_batch=64))
    with pytest.raises(ValueError, match="server_batch"):
        Engine(cfg, task=task, fed=fed, log=lambda *a, **k: None)
    # ...but a null/zero-churn scenario at fixed attendance is fine
    Engine(_cfg(cycle=CycleConfig(server_batch=64)), task=task, fed=fed,
           log=lambda *a, **k: None)


def test_churn_requires_padded_cohorts():
    cfg = _cfg(pad_cohorts=False,
               scenario=ScenarioConfig(kind="uniform", dropout=0.2))
    with pytest.raises(ValueError, match="pad_cohorts"):
        cfg.validate()


# --------------------------------------------------------- serialization
def test_scenario_config_round_trip():
    sc = ScenarioConfig(kind="diurnal-churn", dropout=0.1, straggler=0.5,
                        staleness_bound=3, period=24, amplitude=0.5, seed=7)
    assert ScenarioConfig.from_dict(sc.to_dict()) == sc
    with pytest.raises(KeyError, match="unknown"):
        ScenarioConfig.from_dict({"kind": "uniform", "nope": 1})
    with pytest.raises(KeyError, match="unknown scenario kind"):
        ScenarioConfig(kind="wat").validate()


def test_experiment_config_scenario_round_trip():
    cfg = ExperimentConfig(
        scenario=ScenarioConfig(kind="pareto-straggler", straggler=1.5))
    back = ExperimentConfig.from_dict(cfg.to_dict())
    assert back == cfg
    assert isinstance(back.scenario, ScenarioConfig)
    # pre-scenario JSONs (no key) load as the null scenario
    d = cfg.to_dict()
    del d["scenario"]
    assert ExperimentConfig.from_dict(d).scenario == ScenarioConfig()


def test_scenario_from_flags_round_trip():
    ap = ExperimentConfig.add_arguments(argparse.ArgumentParser())
    args = ap.parse_args([
        "--scenario", "diurnal-churn", "--scenario-dropout", "0.2",
        "--scenario-straggler", "0.5", "--scenario-staleness-bound", "2",
        "--scenario-period", "24", "--scenario-amplitude", "0.4",
        "--scenario-seed", "9"])
    cfg = ExperimentConfig.from_flags(args)
    assert cfg.scenario == ScenarioConfig(
        kind="diurnal-churn", dropout=0.2, straggler=0.5, staleness_bound=2,
        period=24, amplitude=0.4, seed=9)
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


def test_scenario_kinds_registry():
    assert scenario_kinds()[0] == "none"
    assert set(scenario_kinds()[1:]) == set(STREAMS)


# ----------------------------------------------------------- population
def test_population_simulator_smoke():
    """100k virtual clients, one small server: the run touches only the
    cohorts that attended, compiles once, and reports churn telemetry."""
    from repro.scenario.population import PopulationSpec, run_population
    spec = PopulationSpec(n_clients=100_000, test_size=256)
    res = run_population(spec, ScenarioConfig(kind="uniform", dropout=0.2),
                         cohort=8, rounds=3, batch=4, width=8)
    pop = res["population"]
    assert pop["n_clients"] == 100_000
    assert pop["trace_count"] == 1
    assert pop["clients_materialized"] <= 8 * 3
    assert res["telemetry"]["dropped_total"] >= 0
    assert res["history"][-1]["accuracy"] > 0


def test_population_lazy_and_deterministic():
    from repro.scenario.population import PopulationFed, PopulationSpec
    spec = PopulationSpec(n_clients=50_000, samples_per_client=12, seed=4)
    fed_a, fed_b = PopulationFed(spec), PopulationFed(spec)
    assert fed_a.n_clients == 50_000 and fed_a.materialized == 0
    c = fed_a.materialize(31_337)
    np.testing.assert_array_equal(c.x_train,
                                  fed_b.materialize(31_337).x_train)
    assert fed_a.materialized == 1
    assert len(c.x_train) + len(c.x_test) == 12
    xa, ya = fed_a.test_arrays()
    xb, _ = fed_b.test_arrays()
    np.testing.assert_array_equal(xa, xb)
    assert len(xa) == spec.test_size and len(ya) == spec.test_size
