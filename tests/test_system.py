"""End-to-end behaviour tests for the CycleSL system.

The headline integration test trains the synthetic non-iid federated
task with CycleSFL for a handful of rounds and checks it actually
learns (accuracy well above chance) — the full pipeline: data gen ->
Dirichlet split -> attendance sampling -> split model -> Algorithm 1 ->
per-protocol evaluation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import build_task, evaluate, run
from repro.core.algorithms import make_algorithm
from repro.core.cyclesl import CycleConfig
from repro.data.federated import sample_cohort
from repro.optim import adam


def test_cyclesfl_learns_end_to_end():
    res = run("cyclesfl", task_name="image", rounds=30, n_clients=40,
              attendance=0.2, eval_every=30, width=8,
              log=lambda *a, **k: None)
    final = res["history"][-1]
    assert final["accuracy"] > 0.25          # 10 classes -> chance 0.1
    assert np.isfinite(final["test_loss"])
    assert "grad_stability" in res


def test_cycle_beats_baseline_on_convergence_speed():
    """Paper Table 14's headline: the cycle variant makes progress much
    earlier than its aggregation-based original."""
    accs = {}
    for algo in ("sflv1", "cyclesfl"):
        res = run(algo, task_name="image", rounds=20, n_clients=40,
                  attendance=0.2, eval_every=10, width=8, seed=1,
                  log=lambda *a, **k: None)
        accs[algo] = res["history"][0]["accuracy"]   # after 10 rounds
    assert accs["cyclesfl"] > accs["sflv1"], accs


def test_regression_task_end_to_end():
    res = run("cyclepsl", task_name="gaze", rounds=40, n_clients=20,
              attendance=0.3, eval_every=10, log=lambda *a, **k: None)
    hist = res["history"]
    assert all(np.isfinite(h["test_loss"]) for h in hist)
    assert hist[-1]["test_loss"] < hist[0]["test_loss"]   # it learns


def test_charlm_task_end_to_end():
    res = run("cyclesfl", task_name="charlm", rounds=8, n_clients=10,
              attendance=0.3, eval_every=8, log=lambda *a, **k: None)
    assert np.isfinite(res["history"][-1]["test_loss"])


def test_per_client_eval_used_for_psl_family():
    task, fed, _ = build_task("image", 20, 0.5, 0, width=4, cut=2)
    algo = make_algorithm("psl", task, adam(1e-3), adam(1e-3), CycleConfig())
    state = algo.init(jax.random.PRNGKey(0), fed.n_clients)
    loss, mets = evaluate(task, state, fed)
    assert np.isfinite(loss) and 0.0 <= mets["accuracy"] <= 1.0


def test_checkpointing_roundtrip_through_driver(tmp_path):
    res = run("cyclesfl", task_name="image", rounds=5, n_clients=10,
              attendance=0.3, eval_every=5, ckpt_dir=str(tmp_path),
              log=lambda *a, **k: None)
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 5
