"""Hypothesis property tests on the system's invariants.

The whole module is skipped when hypothesis isn't installed (it is an
optional dev dependency — see requirements-dev.txt), so the tier-1
suite collects cleanly either way.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.feature_store import (FeatureStore, gather_batch,
                                      masked_resample_plan, resample_plan,
                                      shard_slice_indices)
from repro.kernels import ref
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_init, softcap
from repro.optim import adam
from repro.utils.hlo import collective_stats
from repro.utils.tree import param_count, tree_l2_norm

SETTINGS = dict(max_examples=25, deadline=None)


@given(total=st.integers(8, 200), epochs=st.integers(1, 4),
       batch=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_resample_plan_permutation_property(total, epochs, batch, seed):
    """Every epoch draws without replacement and within range (Eq. 3)."""
    batch = min(batch, total)
    plan = resample_plan(jax.random.PRNGKey(seed), total, epochs, batch)
    steps = total // batch
    assert plan.shape == (epochs, steps, batch)
    arr = np.asarray(plan)
    assert arr.min() >= 0 and arr.max() < total
    for e in range(epochs):
        flat = arr[e].ravel()
        assert len(np.unique(flat)) == len(flat)   # no replacement


@given(n_live=st.integers(2, 40), pad=st.integers(1, 30),
       epochs=st.integers(1, 3), batch=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_masked_plan_live_sequence_invariant_under_padding(n_live, pad,
                                                          epochs, batch,
                                                          seed):
    """Appending padded rows to the pool must not move a single live row
    in the resample order: each row's sort key is a pure function of
    (epoch key, row id), so the valid-step plan at capacity n_live+pad
    equals the plan at capacity n_live exactly — the shape-invariance
    the padded-vs-unpadded round goldens rest on — and every epoch's
    valid steps draw distinct live rows (a permutation slice)."""
    batch = min(batch, n_live)
    key = jax.random.PRNGKey(seed)
    plan0, ok0 = masked_resample_plan(key, jnp.ones(n_live), epochs, batch)
    valid = jnp.concatenate([jnp.ones(n_live), jnp.zeros(pad)])
    plan, ok = masked_resample_plan(key, valid, epochs, batch)
    live_steps = n_live // batch
    assert bool(jnp.all(ok0))
    assert bool(jnp.all(ok[:, :live_steps]))
    assert not bool(jnp.any(ok[:, live_steps:]))
    np.testing.assert_array_equal(np.asarray(plan[:, :live_steps]),
                                  np.asarray(plan0))
    arr = np.asarray(plan[:, :live_steps])
    for e in range(epochs):
        flat = arr[e].ravel()
        assert len(np.unique(flat)) == len(flat)      # no replacement
        assert flat.size == 0 or flat.max() < n_live  # live rows only


@given(mask=st.lists(st.booleans(), min_size=4, max_size=60),
       epochs=st.integers(1, 3), batch=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_masked_plan_never_selects_padded_rows(mask, epochs, batch, seed):
    """No padded row ever reaches a server minibatch, for ARBITRARY
    live/padded interleavings (not just suffix padding): every index in
    a step the validity mask marks ok points at a live pooled row."""
    valid = jnp.asarray(mask, jnp.float32)
    plan, ok = masked_resample_plan(jax.random.PRNGKey(seed), valid,
                                    epochs, batch)
    selected = np.asarray(plan)[np.asarray(ok)]       # [valid steps, batch]
    assert np.asarray(valid)[selected.ravel().astype(int)].min(
        initial=1.0) > 0
    # step accounting: exactly n_valid // batch steps are ok per epoch
    n_valid = int(np.asarray(valid).sum())
    np.testing.assert_array_equal(
        np.asarray(ok).sum(axis=-1), n_valid // batch)


@pytest.mark.kernels
@given(shards=st.integers(1, 8), rows=st.integers(1, 16),
       m=st.integers(1, 32), d=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_shard_index_translation_partitions_global_gather(shards, rows, m,
                                                          d, seed):
    """The shard-local resample's index-translation contract: for ANY
    pool slicing, each global index lands in exactly one shard's slice
    (the ok masks partition the gather), and the union of shard-local
    work — masked local gathers summed across shards, exactly the
    shard_map body's cross-shard fixup — reconstructs
    ``jnp.take(pool, idx, 0)`` bit-for-bit."""
    rng = np.random.default_rng(seed)
    total = shards * rows
    pool = jnp.asarray(rng.normal(size=(total, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, total, size=m), jnp.int32)
    claims = np.zeros(m, np.int64)
    out = jnp.zeros((m, d), jnp.float32)
    for s in range(shards):
        local, ok = shard_slice_indices(idx, s, rows)
        assert bool(jnp.all((local >= 0) & (local < rows)))   # safe index
        contrib = jnp.where(np.asarray(ok)[:, None],
                            jnp.take(pool[s * rows:(s + 1) * rows], local,
                                     axis=0), 0.0)
        claims += np.asarray(ok, np.int64)
        out = out + contrib
    np.testing.assert_array_equal(claims, np.ones(m))          # partition
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.take(pool, idx, axis=0)))


@pytest.mark.kernels
@given(shards=st.integers(1, 8), live_cohorts=st.integers(1, 6),
       pad_cohorts=st.integers(0, 4), b=st.integers(1, 6),
       batch=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_masked_plan_padded_rows_never_enter_any_shard(shards, live_cohorts,
                                                       pad_cohorts, b, batch,
                                                       seed):
    """Padded pool rows stay out of the shard-local gather entirely: for
    random capacities, masks, and shard counts, every index of every
    VALID step of the masked plan is claimed by exactly one shard and
    points at a LIVE row — so no shard ever does fixup work for a
    padded row and no padded row crosses a shard boundary."""
    total = (live_cohorts + pad_cohorts) * b
    rows = total // shards
    if rows * shards != total:      # keep only even slicings (the shard-
        rows, shards = total, 1     # local path falls back otherwise)
    valid = jnp.repeat(
        jnp.concatenate([jnp.ones(live_cohorts), jnp.zeros(pad_cohorts)]), b)
    batch = min(batch, live_cohorts * b)
    plan, ok = masked_resample_plan(jax.random.PRNGKey(seed), valid, 2, batch)
    selected = np.asarray(plan)[np.asarray(ok)].ravel()        # valid steps
    for g in selected:
        owners = [s for s in range(shards)
                  if bool(shard_slice_indices(jnp.asarray([g]), s, rows)[1][0])]
        assert len(owners) == 1                                 # one shard
        assert float(valid[int(g)]) > 0                         # live row


@given(c=st.integers(1, 5), b=st.integers(1, 8), d=st.integers(1, 8),
       seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_feature_store_pool_gather_roundtrip(c, b, d, seed):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.normal(size=(c, b, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 5, size=(c, b)))
    store = FeatureStore.pool(f, y)
    idx = jnp.arange(store.size)
    got_f, got_y = gather_batch(store, idx)
    np.testing.assert_allclose(np.asarray(got_f),
                               np.asarray(f.reshape(-1, d)), atol=0)
    np.testing.assert_array_equal(np.asarray(got_y),
                                  np.asarray(y.reshape(-1)))


@given(s=st.integers(2, 32), h=st.integers(1, 4),
       dh=st.sampled_from([4, 8, 16]), seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_rope_preserves_norm(s, h, dh, seed):
    """Rotary embedding is a rotation: per-head vector norms unchanged."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, s, h, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (1, s))
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-4)


@given(cap=st.floats(1.0, 100.0), seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_softcap_bounds(cap, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * 1000, jnp.float32)
    y = softcap(x, cap)
    assert float(jnp.max(jnp.abs(y))) <= cap * (1 + 1e-6)
    # monotone up to f32 rounding at tanh saturation (eps scales with cap)
    xs = jnp.sort(x)
    assert bool(jnp.all(jnp.diff(softcap(xs, cap)) >= -1e-5 * cap))


@given(d=st.sampled_from([4, 16, 64]), scale=st.floats(0.5, 10.0),
       seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_rmsnorm_scale_invariance(d, scale, seed):
    """RMSNorm(s·x) ≈ RMSNorm(x) — exact up to the eps regularizer, so
    keep inputs with var >> eps and scale >= 0.5."""
    rng = np.random.default_rng(seed)
    p = rmsnorm_init(d)
    x = jnp.asarray(rng.normal(size=(3, d)) * 2.0 + 0.5, jnp.float32)
    a = rmsnorm(p, x)
    b = rmsnorm(p, x * scale)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=5e-3, rtol=5e-3)


@given(t=st.integers(1, 64), e=st.sampled_from([4, 8, 16]),
       k=st.integers(1, 4), seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_topk_gating_ref_properties(t, e, k, seed):
    k = min(k, e)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(t, e)), jnp.float32)
    w, ids = ref.topk_gating_ref(logits, k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(t), atol=1e-5)
    assert bool((w >= 0).all())
    # ids are distinct per token
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == k


@given(seed=st.integers(0, 1000), steps=st.integers(1, 20))
@settings(**SETTINGS)
def test_adam_updates_bounded_by_lr(seed, steps):
    """|Adam update| <= ~lr/(1-b1) per coordinate — stability invariant."""
    rng = np.random.default_rng(seed)
    opt = adam(1e-2)
    params = {"w": jnp.zeros((8,))}
    state = opt.init(params)
    for s in range(steps):
        g = {"w": jnp.asarray(rng.normal(size=8) * 10, jnp.float32)}
        upd, state = opt.update(g, state, params, s)
        assert float(jnp.max(jnp.abs(upd["w"]))) < 1e-2 * 10.5


def test_collective_stats_parses_synthetic_hlo():
    text = """
ENTRY %main (p0: f32[128,8]) -> f32[128,8] {
  %p0 = f32[128,8]{1,0} parameter(0)
  %ag = f32[1024,8]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = f32[128,8]{1,0} all-reduce(%p0), to_apply=%add
  ROOT %out = f32[128,8]{1,0} copy(%ar)
}
"""
    stats = collective_stats(text)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.bytes_by_kind["all-gather"] == 128 * 8 * 4
    assert stats.bytes_by_kind["all-reduce"] == 128 * 8 * 4


@given(kind=st.sampled_from(["uniform", "pareto-straggler", "diurnal-churn"]),
       n=st.integers(4, 60), c=st.integers(1, 12),
       dropout=st.floats(0.0, 0.9), straggler=st.floats(0.0, 3.0),
       rounds=st.lists(st.integers(0, 500), min_size=1, max_size=6),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_profile_stream_deterministic_under_replay(kind, n, c, dropout,
                                                   straggler, rounds, seed):
    """Every stream draw is a pure fold-in of (seed, salt, round): two
    independently-built streams agree on profiles, weights, and events
    for ANY round query order — the property that lets resume skip
    event replay entirely."""
    from repro.scenario.profiles import ScenarioConfig, build_profile_stream
    cfg = ScenarioConfig(kind=kind, dropout=dropout, straggler=straggler)
    a = build_profile_stream(cfg, n, seed)
    b = build_profile_stream(cfg, n, seed)
    rng = np.random.default_rng(seed)
    cohort = rng.choice(n, size=min(c, n), replace=False)
    for rnd in rounds + rounds[::-1]:          # out-of-order + repeated
        ea = a.events(rnd, cohort, min_live=1)
        eb = b.events(rnd, cohort, min_live=1)
        np.testing.assert_array_equal(ea.keep, eb.keep)
        np.testing.assert_array_equal(ea.lag, eb.lag)
        assert (ea.hazard_drops, ea.deadline_drops) == \
            (eb.hazard_drops, eb.deadline_drops)
        wa, wb = a.weights(rnd), b.weights(rnd)
        assert (wa is None) == (wb is None)
        if wa is not None:
            np.testing.assert_array_equal(wa, wb)
        assert ea.keep.sum() >= 1              # min_live revival floor
    assert a.profile(int(cohort[0])) == b.profile(int(cohort[0]))


@given(live=st.integers(2, 16), pad=st.integers(0, 8),
       b=st.integers(1, 6), batch=st.integers(1, 8),
       dropout=st.floats(0.1, 0.9), rnd=st.integers(0, 200),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_dropped_slot_features_never_reach_server_minibatch(live, pad, b,
                                                            batch, dropout,
                                                            rnd, seed):
    """End-to-end churn invariant over the Engine's exact dataflow:
    stream events -> attendance mask x keep -> pooled row validity ->
    masked resample plan.  Every row of every VALID server step maps to
    a slot that both attended (not padding) and survived the round."""
    from repro.core.feature_store import valid_from_mask
    from repro.scenario.profiles import ScenarioConfig, build_profile_stream
    n = live * 4
    stream = build_profile_stream(
        ScenarioConfig(kind="uniform", dropout=dropout), n, seed)
    cohort = np.random.default_rng(seed).choice(n, size=live, replace=False)
    ev = stream.events(rnd, cohort, min_live=1)
    mask = np.concatenate([np.ones(live, np.float32),
                           np.zeros(pad, np.float32)])
    mask[:live] *= ev.keep                     # mid-round drops, in place
    batch = min(batch, max(1, int(mask.sum()) * b))
    valid = valid_from_mask(jnp.asarray(mask), b)
    plan, ok = masked_resample_plan(jax.random.PRNGKey(seed), valid, 2, batch)
    selected = np.asarray(plan)[np.asarray(ok)].ravel()
    slots = selected // b                      # pooled row -> cohort slot
    assert slots.size == 0 or mask[slots].min() > 0
    # accounting: valid steps cover exactly the surviving rows' worth
    n_valid = int(mask.sum()) * b
    np.testing.assert_array_equal(np.asarray(ok).sum(axis=-1),
                                  n_valid // batch)


@given(c=st.integers(2, 6), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_client_phase_is_cohort_permutation_equivariant(c, seed):
    """Renaming clients permutes their gradients/updates 1:1 — the
    aggregation-free symmetry of the frozen-server client phase (Eq. 5).
    (The server inner loop itself is position-seeded by design, so the
    full round is only equivariant in distribution.)"""
    from repro.core.cyclesl import CycleConfig, client_updates, feature_gradients
    from repro.core.protocol import broadcast_entity, init_entity
    from repro.core.split import make_stage_task
    from repro.models.cnn import mlp
    from repro.optim import sgd

    rng = np.random.default_rng(seed)
    task = make_stage_task(mlp(6, [8], 3), cut=1)
    opt = sgd(0.05)
    server = init_entity(task.init_server(jax.random.PRNGKey(0)), opt)
    clients = broadcast_entity(
        init_entity(task.init_client(jax.random.PRNGKey(1)), opt), c)
    xs = jnp.asarray(rng.normal(size=(c, 4, 6)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, 3, size=(c, 4)))
    perm = np.asarray(rng.permutation(c))
    ccfg = CycleConfig()

    feats = jax.vmap(task.client_forward)(clients.params, xs)
    g1 = feature_gradients(task, server.params, feats, ys, ccfg)
    g2 = feature_gradients(task, server.params, feats[perm], ys[perm], ccfg)
    np.testing.assert_allclose(np.asarray(g1)[perm], np.asarray(g2), atol=1e-6)

    c1, _ = client_updates(task, clients, opt, xs, g1)
    c2, _ = client_updates(task, clients, opt, xs[perm], g1[perm])
    for a, b in zip(jax.tree.leaves(c1.params), jax.tree.leaves(c2.params)):
        np.testing.assert_allclose(np.asarray(a)[perm], np.asarray(b),
                                   atol=1e-5)


@given(live=st.integers(2, 16), pad=st.integers(0, 8),
       b=st.integers(1, 6), batch=st.integers(1, 8),
       nbad=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_quarantined_slot_features_never_reach_server_minibatch(live, pad, b,
                                                                batch, nbad,
                                                                seed):
    """Quarantine soundness over the Engine's exact recovery dataflow:
    slot blame -> quarantine_mask -> pooled row validity -> masked
    resample plan.  A blamed slot's mask entry reads 0, so none of its
    pooled feature rows can appear in any VALID server step of the
    re-run — its NaN payload is structurally excluded, which is the
    whole reason the quarantine re-dispatch converges."""
    from repro.core.feature_store import valid_from_mask
    from repro.resilience.policy import quarantine_mask
    rng = np.random.default_rng(seed)
    mask = np.concatenate([np.ones(live, np.float32),
                           np.zeros(pad, np.float32)])
    slot_bad = np.zeros(live + pad, np.float32)
    bad = rng.choice(live, size=min(nbad, live - 1), replace=False)
    slot_bad[bad] = 1.0                        # guards only blame LIVE slots
    qmask = quarantine_mask(mask, slot_bad)
    assert qmask[bad].max() == 0               # blamed slots excised
    np.testing.assert_array_equal(              # everyone else untouched
        np.delete(qmask, bad), np.delete(mask, bad))
    batch = min(batch, max(1, int(qmask.sum()) * b))
    valid = valid_from_mask(jnp.asarray(qmask), b)
    plan, ok = masked_resample_plan(jax.random.PRNGKey(seed), valid, 2, batch)
    selected = np.asarray(plan)[np.asarray(ok)].ravel()
    slots = selected // b                      # pooled row -> cohort slot
    assert slots.size == 0 or qmask[slots].min() > 0
    assert slots.size == 0 or not np.intersect1d(slots, bad).size
    # accounting: valid steps cover exactly the surviving rows' worth
    n_valid = int(qmask.sum()) * b
    np.testing.assert_array_equal(np.asarray(ok).sum(axis=-1),
                                  n_valid // batch)
