"""Pipelined rounds: the equivalence suite that locks the scheduler down.

The tentpole contract (ISSUE 4, generalized to depth L by ISSUE 10):
restructuring RoundProgram execution into a software pipeline over an
L-deep ring of in-flight cohorts must not change a single bit where the
schedules are required to agree:

1. **Sync barrier == sequential, bit-for-bit — at ANY depth.**
   ``pipeline_staleness='sync'`` reproduces the sequential Engine
   exactly — per-round TrainState and metrics — for ALL 10 registered
   algorithms (fused programs fall back to the monolithic round and are
   trivially covered; the split programs are the real test); the ring
   degenerates to one barriered stage whatever ``pipeline_depth`` says.
2. **Trace budget.**  One extract trace + one tail trace per (algo,
   config, mesh) across varying live cohort sizes AND any ring depth —
   the sequential budget (one round trace) plus at most one pipeline
   warm-up trace.
3. **Bounded staleness.**  Async mode's θ_S/client lag never exceeds
   ``pipeline_depth``: the Engine's schedule is pinned against manual
   re-executions of the stale recurrence at depth 1 and depth 2
   (prime lags 0..L-1, steady-state lag exactly L).
4. **Resume.**  A resumed sync pipelined run is bit-for-bit the
   uninterrupted pipelined run at any depth; async resume re-primes
   the ring from the restored state and keeps the lag bound.
5. **Staleness weighting.**  ``staleness_weighting != 'none'`` scales
   each cohort's server/feature gradients by w(realized lag) inside
   the one compiled tail; w(0) == 1.0 exactly, so sync schedules are a
   numerical no-op vs unweighted (allclose — the traced multiply may
   re-fuse downstream reductions, shifting them by an ulp) while async
   runs genuinely
   change; ``'none'`` keeps the tail's historical signature bit-for-bit.
"""
import json
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Engine, ExperimentConfig, PROGRAMS, build_algorithm,
                       build_pipelined_algorithm, get_program, split_program)
from repro.core.cyclesl import CycleConfig, cyclesl_extract, cyclesl_round, \
    cyclesl_tail
from repro.core.protocol import init_entity, broadcast_entity
from repro.launch.meshcheck import C, _masks, _task_and_data
from repro.optim import adam


@pytest.fixture(scope="module")
def setup():
    # the same task/data protocol the meshcheck and padded goldens use
    return _task_and_data()


class Rec:
    def __init__(self):
        self.rows, self.state = [], None

    def on_round(self, engine, rnd, state, metrics):
        self.rows.append({k: np.asarray(v) for k, v in metrics.items()})
        self.state = state


def _assert_equal(a_state, a_rows, b_state, b_rows, msg):
    for i, (ra, rb) in enumerate(zip(a_rows, b_rows)):
        for k in ra:
            np.testing.assert_array_equal(
                ra[k], rb[k], err_msg=f"{msg}: round {i} metric {k}")
    for la, lb in zip(jax.tree.leaves(a_state), jax.tree.leaves(b_state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{msg}: state")


def _cfg(algo, **kw):
    base = dict(algo=algo, task="image", rounds=4, n_clients=8,
                attendance=0.5, batch=4, width=4, eval_every=4, seed=0)
    base.update(kw)
    return ExperimentConfig(**base)


def _run(cfg):
    rec = Rec()
    res = Engine(cfg, callbacks=(rec,), log=lambda *a, **k: None).run()
    return rec, res


# --------------------------------------------------- per-algorithm goldens
@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_pipelined_sync_engine_is_bit_for_bit_sequential(name):
    """The tentpole golden: the full pipelined Engine path (split
    dispatches, prefetched sampling, double-buffered stage) in sync
    barrier mode equals the sequential Engine exactly, per round, for
    every registered algorithm."""
    r_seq, _ = _run(_cfg(name))
    r_pipe, res = _run(_cfg(name, pipeline_depth=1))
    _assert_equal(r_seq.state, r_seq.rows, r_pipe.state, r_pipe.rows, name)
    split = split_program(get_program(name)) is not None
    assert res["pipeline"]["active"] == split, (
        f"{name}: fused programs must fall back to the monolithic round")


@pytest.mark.parametrize("depth", [2, 4])
@pytest.mark.parametrize("name", ["cyclesfl", "psl"])
def test_deep_sync_pipeline_is_bit_for_bit_sequential(name, depth):
    """Depth-L generalization of the sync golden: whatever the
    configured depth, the sync barrier means extract(k+1) waits for
    Commit(k) — the ring degenerates to one in-flight stage and the run
    is bit-for-bit the sequential Engine."""
    r_seq, _ = _run(_cfg(name))
    r_pipe, res = _run(_cfg(name, pipeline_depth=depth))
    _assert_equal(r_seq.state, r_seq.rows, r_pipe.state, r_pipe.rows,
                  f"{name} depth={depth}")
    assert res["pipeline"]["ring_depth"] == 1
    assert res["pipeline"]["max_theta_s_lag_rounds"] == 0


@pytest.mark.parametrize("name", sorted(n for n in PROGRAMS
                                        if split_program(get_program(n))))
def test_split_round_matches_monolithic_bit_for_bit(name, setup):
    """Algorithm-level golden under padding: extract ∘ tail equals the
    monolithic jitted round exactly, across rounds with varying live
    cohort sizes (the masked compile-once stream)."""
    task, xs, ys = setup
    opt = adam(5e-3)
    ccfg = CycleConfig(server_epochs=2)
    algo = build_algorithm(get_program(name), task, opt, opt, ccfg)
    pipe = build_pipelined_algorithm(get_program(name), task, opt, opt, ccfg)
    s_mono = algo.init(jax.random.PRNGKey(0), n_clients=C)
    s_pipe = algo.init(jax.random.PRNGKey(0), n_clients=C)
    cohort = jnp.arange(C)
    for r, mask in enumerate(_masks()):
        key = jax.random.PRNGKey(r)
        s_mono, m_mono = algo.round(s_mono, cohort, xs, ys, key, mask)
        stage = pipe.extract(s_pipe, cohort, xs, ys, mask)
        s_pipe, m_pipe = pipe.tail(s_pipe, cohort, xs, ys, key, stage, mask)
        for k in m_mono:
            np.testing.assert_array_equal(
                np.asarray(m_mono[k]), np.asarray(m_pipe[k]),
                err_msg=f"{name} round {r}: metric {k}")
    for la, lb in zip(jax.tree.leaves(s_mono), jax.tree.leaves(s_pipe)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{name}: state")


def test_fused_programs_have_no_split():
    for name in ("ssl", "sflv2", "fedavg"):
        assert split_program(get_program(name)) is None
        assert build_pipelined_algorithm(get_program(name), *([None] * 3)) \
            is None


# --------------------------------------------------------- trace budget
@pytest.mark.parametrize("name", ["cyclesfl", "psl"])
def test_pipelined_trace_budget_across_varying_cohorts(name):
    """Compile pin: ONE extract trace + ONE tail trace for the whole
    experiment no matter how live attendance varies — the sequential
    round budget plus at most one pipeline warm-up trace."""
    cfg = _cfg(name, rounds=6, n_clients=24, attendance=0.25,
               variable_attendance=True, pipeline_depth=1)
    eng = Engine(cfg, log=lambda *a, **k: None)
    eng.run()
    assert eng.pipeline.extract_traces == 1, (
        f"{name}: extract traced {eng.pipeline.extract_traces} times")
    assert eng.pipeline.tail_traces == 1, (
        f"{name}: tail traced {eng.pipeline.tail_traces} times")
    assert eng.algo.trace_count == 0, (
        f"{name}: the monolithic round must not trace on the pipelined path")


def test_deep_ring_trace_budget_across_varying_cohorts():
    """The compile contract survives depth L: a depth-4 async ring over
    varying live cohorts (and with staleness weighting active, whose lag
    rides in as a traced scalar) still traces ONE extract and ONE tail."""
    cfg = _cfg("cyclesfl", rounds=8, n_clients=24, attendance=0.25,
               variable_attendance=True, pipeline_depth=4,
               pipeline_staleness="async", staleness_weighting="exp")
    eng = Engine(cfg, log=lambda *a, **k: None)
    res = eng.run()
    assert eng.pipeline.extract_traces == 1
    assert eng.pipeline.tail_traces == 1
    assert eng.algo.trace_count == 0
    assert res["pipeline"]["max_theta_s_lag_rounds"] <= 4


# ------------------------------------------------------------- staleness
def test_async_theta_s_lag_never_exceeds_one_round():
    """The staleness contract: in async mode every consumed stage was
    extracted from the immediately preceding round's state — lag is
    exactly one round after warm-up, never more."""
    for name in ("cyclesfl", "psl"):
        _, res = _run(_cfg(name, pipeline_depth=1,
                           pipeline_staleness="async"))
        assert res["pipeline"]["max_theta_s_lag_rounds"] == 1, name
    # sync barrier mode has no staleness at all
    _, res = _run(_cfg("cyclesfl", pipeline_depth=1))
    assert res["pipeline"]["max_theta_s_lag_rounds"] == 0


@pytest.mark.parametrize("depth,rounds", [(2, 6), (3, 6)])
def test_async_lag_bounded_by_depth(depth, rounds):
    """Depth-L bound: per-cohort realized lags warm up 0..L-1 (prime
    extracts read the initial state) then hold at exactly L — never
    more.  Pinned against the exact expected lag sequence."""
    _, res = _run(_cfg("cyclesfl", rounds=rounds, pipeline_depth=depth,
                       pipeline_staleness="async"))
    lags = res["pipeline"]["realized_lags"]
    want = [min(r, depth) for r in range(rounds)]
    assert lags == want, (lags, want)
    assert res["pipeline"]["max_theta_s_lag_rounds"] == depth
    # per-round telemetry carries the same realized lags
    tel = [r["realized_lag"] for r in res["telemetry"]["per_round"]]
    assert tel == want


def test_async_engine_matches_manual_one_round_stale_schedule():
    """Pin the async schedule itself: re-execute the one-round-stale
    recurrence by hand — stage(k+1) extracted from the PRE-tail state of
    round k — and require the Engine's async run to match bit-for-bit.
    (If the Engine ever consumed a stage older than one round, or a
    fresh one, this diverges.)"""
    cfg = _cfg("cyclesfl", pipeline_depth=1, pipeline_staleness="async")
    r_async, _ = _run(cfg)

    eng = Engine(cfg, log=lambda *a, **k: None)
    state = eng.init_state()
    rng = np.random.default_rng(cfg.seed + 1)
    inputs = eng.sample_round(rng)
    stage = eng._extract(state, inputs)            # warm-up: lag 0
    rows, final = [], None
    for rnd in range(cfg.rounds):
        nxt_inputs = (eng.sample_round(rng)
                      if rnd + 1 < cfg.rounds else None)
        nxt = (eng._extract(state, nxt_inputs)     # pre-tail state: lag 1
               if nxt_inputs is not None else None)
        state, metrics = eng._tail(state, inputs, stage, eng.round_key(rnd))
        rows.append({k: np.asarray(v) for k, v in metrics.items()})
        stage, inputs = nxt, nxt_inputs
    _assert_equal(r_async.state, r_async.rows, state, rows, "async schedule")


def test_async_engine_matches_manual_depth2_stale_schedule():
    """The depth-L schedule golden: re-execute the depth-2 bounded-stale
    recurrence by hand — the first L stages extracted from the initial
    state (lags 0..L-1 at consumption), then stage(k+L) extracted from
    the PRE-tail state of round k (steady-state lag exactly L) — and
    require the Engine's depth-2 async run to match bit-for-bit.  (If
    the Engine ever consumed a stage older than L rounds, a fresher one,
    or drew cohorts out of round order, this diverges.)"""
    L = 2
    cfg = _cfg("cyclesfl", rounds=5, pipeline_depth=L,
               pipeline_staleness="async")
    r_async, _ = _run(cfg)

    eng = Engine(cfg, log=lambda *a, **k: None)
    state = eng.init_state()
    rng = np.random.default_rng(cfg.seed + 1)
    ring = []
    for _ in range(min(L, cfg.rounds)):            # prime from init state
        ins = eng.sample_round(rng)
        ring.append((eng._extract(state, ins), ins))
    rows = []
    for rnd in range(cfg.rounds):
        stage, inputs = ring.pop(0)
        if rnd + L < cfg.rounds:
            nxt_inputs = eng.sample_round(rng)     # round order: rnd + L
            # pre-tail state of round rnd: consumed at rnd + L -> lag L
            ring.append((eng._extract(state, nxt_inputs), nxt_inputs))
        state, metrics = eng._tail(state, inputs, stage, eng.round_key(rnd))
        rows.append({k: np.asarray(v) for k, v in metrics.items()})
    _assert_equal(r_async.state, r_async.rows, state, rows,
                  "depth-2 async schedule")


def test_async_equals_sync_when_staleness_cannot_bind(setup):
    """With per-client commits and non-overlapping consecutive cohorts,
    one-round-stale client reads touch clients no previous round wrote,
    and the cycle family never reads the θ_S^t snapshot — so async and
    sync must agree bit-for-bit.  A behavioural proof that staleness
    enters ONLY through the one-round window."""
    task, xs, ys = setup
    opt = adam(5e-3)
    ccfg = CycleConfig(server_epochs=2)
    pipe = build_pipelined_algorithm(get_program("cyclepsl"), task, opt, opt,
                                    ccfg)
    half = C // 2
    cohorts = [jnp.arange(half), jnp.arange(half, C)]   # disjoint
    mask = jnp.ones(half, jnp.float32)

    def drive(async_mode):
        state = pipe.init(jax.random.PRNGKey(0), n_clients=C)
        ins = [(cohorts[r % 2], xs[:half] if r % 2 == 0 else xs[half:],
                ys[:half] if r % 2 == 0 else ys[half:]) for r in range(4)]
        stage = pipe.extract(state, *ins[0], mask)
        for rnd in range(4):
            nxt = None
            if rnd + 1 < 4 and async_mode:
                # pre-tail state: the async one-round-stale read
                nxt = pipe.extract(state, *ins[rnd + 1], mask)
            state, _ = pipe.tail(state, *ins[rnd], jax.random.PRNGKey(rnd),
                                 stage, mask)
            if rnd + 1 < 4 and nxt is None:
                nxt = pipe.extract(state, *ins[rnd + 1], mask)
            stage = nxt
        return state

    s_sync, s_async = drive(False), drive(True)
    for la, lb in zip(jax.tree.leaves(s_sync), jax.tree.leaves(s_async)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_async_differs_from_sync_when_cohorts_overlap():
    """Sanity that the async mode is genuinely overlapped (not secretly
    running the barrier): with a shared global client model, one-round
    staleness must change the numbers."""
    r_sync, _ = _run(_cfg("cyclesfl", pipeline_depth=1))
    r_async, _ = _run(_cfg("cyclesfl", pipeline_depth=1,
                           pipeline_staleness="async"))
    same = all(
        np.array_equal(np.asarray(la), np.asarray(lb))
        for la, lb in zip(jax.tree.leaves(r_sync.state),
                          jax.tree.leaves(r_async.state)))
    assert not same, "async run is bit-identical to sync — no overlap?"


# ---------------------------------------------------------------- resume
def test_pipelined_resume_matches_uninterrupted_pipelined_run(tmp_path):
    """Satellite golden: ExperimentConfig.resume of a pipeline_depth=1
    run is bit-for-bit the uninterrupted pipelined run — state, eval
    history tail, and cohort stream all aligned."""
    base = _cfg("cyclesfl", rounds=6, eval_every=2, pipeline_depth=1)
    ra = Rec()
    full = Engine(replace(base, ckpt_dir=str(tmp_path / "a")),
                  callbacks=(ra,), log=lambda *a, **k: None).run()
    dir_b = str(tmp_path / "b")
    Engine(replace(base, rounds=4, ckpt_dir=dir_b),
           log=lambda *a, **k: None).run()
    rb = Rec()
    resumed = Engine(replace(base, ckpt_dir=dir_b, resume=True),
                     callbacks=(rb,), log=lambda *a, **k: None).run()
    assert resumed["resumed_from_round"] == 4
    for la, lb in zip(jax.tree.leaves(ra.state), jax.tree.leaves(rb.state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    tail = [h for h in full["history"] if h["round"] > 4]
    assert [h["round"] for h in resumed["history"]] == \
        [h["round"] for h in tail]
    for got, want in zip(resumed["history"], tail):
        assert got["test_loss"] == want["test_loss"]


def test_deep_sync_resume_matches_uninterrupted_pipelined_run(tmp_path):
    """Depth-2 resume golden: a resumed ``pipeline_depth=2`` sync run is
    bit-for-bit the uninterrupted pipelined run (which is itself the
    sequential run) — the re-primed ring reads the restored state."""
    base = _cfg("cyclesfl", rounds=6, eval_every=2, pipeline_depth=2)
    ra = Rec()
    full = Engine(replace(base, ckpt_dir=str(tmp_path / "a")),
                  callbacks=(ra,), log=lambda *a, **k: None).run()
    dir_b = str(tmp_path / "b")
    Engine(replace(base, rounds=4, ckpt_dir=dir_b),
           log=lambda *a, **k: None).run()
    rb = Rec()
    resumed = Engine(replace(base, ckpt_dir=dir_b, resume=True),
                     callbacks=(rb,), log=lambda *a, **k: None).run()
    assert resumed["resumed_from_round"] == 4
    for la, lb in zip(jax.tree.leaves(ra.state), jax.tree.leaves(rb.state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    tail = [h for h in full["history"] if h["round"] > 4]
    for got, want in zip(resumed["history"], tail):
        assert got["test_loss"] == want["test_loss"]


@pytest.mark.parametrize("depth", [1, 2])
def test_async_resume_reprimes_and_stays_bounded(depth, tmp_path):
    """Async resume re-primes the ring from the restored state (the
    post-resume prime extracts are fresh, like the warm-up rounds); the
    lag bound still holds and the run completes."""
    base = _cfg("cyclesfl", rounds=6, eval_every=2, pipeline_depth=depth,
                pipeline_staleness="async", ckpt_dir=str(tmp_path / "c"))
    Engine(replace(base, rounds=4), log=lambda *a, **k: None).run()
    res = Engine(replace(base, resume=True), log=lambda *a, **k: None).run()
    assert res["resumed_from_round"] == 4
    assert res["pipeline"]["max_theta_s_lag_rounds"] <= depth
    # re-primed lags restart at 0 against the restored state
    assert res["pipeline"]["realized_lags"][:depth] == list(range(depth))


# ------------------------------------------------------------------ mesh
def test_pipelined_engine_on_mesh_matches_sequential():
    """The pipelined mesh path (placed state, committed inputs, pinned
    tail out_shardings, disjoint-axis stage) on a 1-device mesh is
    bit-for-bit the sequential unsharded Engine."""
    r_seq, _ = _run(_cfg("cyclesfl", rounds=3, eval_every=3))
    cfg = _cfg("cyclesfl", rounds=3, eval_every=3, mesh_shape=(1, 1),
               pipeline_depth=1)
    rec = Rec()
    eng = Engine(cfg, callbacks=(rec,), log=lambda *a, **k: None)
    eng.run()
    assert eng.mesh is not None and eng.pipeline is not None
    _assert_equal(r_seq.state, r_seq.rows, rec.state, rec.rows,
                  "pipelined mesh")
    assert eng.pipeline.extract_traces == 1
    assert eng.pipeline.tail_traces == 1


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 (the CI devices8-pipeline leg)")
def test_pipelined_engine_on_8_device_mesh():
    """The CI devices8-pipeline leg: the pipelined Engine on a real
    multi-device host mesh agrees with the sequential unsharded Engine
    to cross-device reduction noise, with the trace budget intact."""
    r_seq, _ = _run(_cfg("cyclesfl", rounds=3, eval_every=3))
    cfg = _cfg("cyclesfl", rounds=3, eval_every=3, mesh_shape=(8, 1),
               pipeline_depth=1)
    rec = Rec()
    eng = Engine(cfg, callbacks=(rec,), log=lambda *a, **k: None)
    eng.run()
    assert eng.pipeline.extract_traces == 1
    assert eng.pipeline.tail_traces == 1
    for la, lb in zip(jax.tree.leaves(r_seq.state),
                      jax.tree.leaves(rec.state)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=2e-5, atol=1e-6)


# ------------------------------------------------------- launcher bundles
def test_cyclesl_extract_tail_compose_to_round(setup):
    """The launcher-side split (core/cyclesl.py): extract ∘ tail is the
    monolithic cyclesl_round, bit-for-bit."""
    task, xs, ys = setup
    opt = adam(5e-3)
    ccfg = CycleConfig(server_epochs=2)
    server = init_entity(task.init_server(jax.random.PRNGKey(0)), opt)
    clients = broadcast_entity(
        init_entity(task.init_client(jax.random.PRNGKey(1)), opt), C)
    key = jax.random.PRNGKey(3)

    s_m, c_m, m_m = jax.jit(
        lambda: cyclesl_round(task, server, clients, opt, opt, xs, ys, key,
                              ccfg))()

    def split_round():
        feats, store = cyclesl_extract(task, clients, xs, ys)
        return cyclesl_tail(task, server, clients, opt, opt, xs, ys, key,
                            ccfg, feats, store)

    s_s, c_s, m_s = jax.jit(split_round)()
    for a, b in zip(jax.tree.leaves((s_m, c_m)), jax.tree.leaves((s_s, c_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in m_m:
        np.testing.assert_array_equal(np.asarray(m_m[k]), np.asarray(m_s[k]))


def test_pipelined_train_step_bundles_lower_and_compile():
    """launch/steps.py: the (train_extract, train_tail) StepBundle pair
    lowers and compiles against the local mesh with the declared
    shardings (the dry-run contract)."""
    from repro.configs import INPUT_SHAPES
    from repro.configs.registry import smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_pipelined_train_steps
    cfg = smoke_config("gemma2-2b")
    shape = next(s for s in INPUT_SHAPES.values() if s.kind == "train")
    mesh = make_local_mesh()
    eb, tb = build_pipelined_train_steps(cfg, mesh, shape)
    assert (eb.name, tb.name) == ("train_extract", "train_tail")
    with mesh:
        jax.jit(eb.fn, in_shardings=eb.in_shardings,
                out_shardings=eb.out_shardings
                ).lower(*eb.abstract_args).compile()
        jax.jit(tb.fn, in_shardings=tb.in_shardings,
                out_shardings=tb.out_shardings,
                donate_argnums=tb.donate
                ).lower(*tb.abstract_args).compile()


# ---------------------------------------------------- staleness weighting
@pytest.mark.parametrize("name", ["cyclesfl", "psl", "sglr"])
@pytest.mark.parametrize("weighting", ["inverse", "exp"])
def test_sync_weighting_is_numerical_noop(name, weighting):
    """w(0) == 1.0 exactly (1/(1+0) and exp(0) are both the IEEE
    constant 1.0), so a sync schedule — lag 0 every round — with
    weighting armed is a numerical no-op across all three ServerUpdate
    modes.  The inserted traced multiply can still change XLA's fusion
    choices (reductions reassociate), so the guarantee is tight
    allclose, not bit equality — bit-for-bit is reserved for
    ``staleness_weighting='none'``, which keeps the tail's exact
    historical signature (the sequential goldens above)."""
    r_plain, _ = _run(_cfg(name, pipeline_depth=1))
    r_w, res = _run(_cfg(name, pipeline_depth=1,
                         staleness_weighting=weighting))
    for i, (ra, rb) in enumerate(zip(r_plain.rows, r_w.rows)):
        for k in ra:
            np.testing.assert_allclose(
                ra[k], rb[k], rtol=1e-5, atol=1e-7,
                err_msg=f"{name}/{weighting}: round {i} metric {k}")
    for la, lb in zip(jax.tree.leaves(r_plain.state),
                      jax.tree.leaves(r_w.state)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-5, atol=1e-7,
                                   err_msg=f"{name}/{weighting}: state")
    # the weight itself is reported and is exactly 1.0 every round
    assert all(float(r["stale_weight"]) == 1.0 for r in r_w.rows)


def test_async_weighting_changes_the_numbers():
    """Sanity that weighting genuinely binds under staleness: a depth-2
    async run with exp weighting diverges from the unweighted depth-2
    async run (lags > 0 scale the server/feature gradients)."""
    r_plain, _ = _run(_cfg("cyclesfl", pipeline_depth=2,
                           pipeline_staleness="async"))
    r_w, _ = _run(_cfg("cyclesfl", pipeline_depth=2,
                       pipeline_staleness="async",
                       staleness_weighting="exp", staleness_lambda=1.0))
    same = all(
        np.array_equal(np.asarray(la), np.asarray(lb))
        for la, lb in zip(jax.tree.leaves(r_plain.state),
                          jax.tree.leaves(r_w.state)))
    assert not same, "staleness weighting changed nothing under lag > 0"
    # the reported weights follow w = exp(-lag): 1.0 on the lag-0 prime
    # round, < 1 once the ring is warm
    ws = [float(r["stale_weight"]) for r in r_w.rows]
    assert ws[0] == 1.0 and all(w < 1.0 for w in ws[1:])


# ---------------------------------------------------------------- config
def test_pipeline_config_json_roundtrip():
    cfg = ExperimentConfig(algo="cyclesfl", pipeline_depth=3,
                           pipeline_staleness="async",
                           staleness_weighting="exp", staleness_lambda=0.25)
    back = ExperimentConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back == cfg


def test_pipeline_config_validation():
    # any depth >= 0 is legal now (the staleness window L); negatives
    # are not
    ExperimentConfig(pipeline_depth=2).validate()
    ExperimentConfig(pipeline_depth=7,
                     pipeline_staleness="async").validate()
    with pytest.raises(ValueError, match="pipeline_depth"):
        ExperimentConfig(pipeline_depth=-1).validate()
    with pytest.raises(ValueError, match="pipeline_staleness"):
        ExperimentConfig(pipeline_depth=1,
                         pipeline_staleness="eager").validate()
    with pytest.raises(ValueError, match="staleness_weighting"):
        ExperimentConfig(staleness_weighting="linear").validate()
    with pytest.raises(ValueError, match="staleness_lambda"):
        ExperimentConfig(staleness_lambda=-0.5).validate()


def test_pipeline_flags():
    import argparse
    ap = ExperimentConfig.add_arguments(argparse.ArgumentParser())
    args = ap.parse_args(["--pipeline-depth", "4",
                          "--pipeline-staleness", "async",
                          "--staleness-weighting", "exp",
                          "--staleness-lambda", "0.25"])
    cfg = ExperimentConfig.from_flags(args)
    assert cfg.pipeline_depth == 4 and cfg.pipeline_staleness == "async"
    assert cfg.staleness_weighting == "exp"
    assert cfg.staleness_lambda == 0.25
