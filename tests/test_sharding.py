"""Sharding-rule tests (pure logic — no multi-device mesh needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import smoke_config
from repro.models.transformer import Transformer
from repro.sharding.specs import (RULES, constrain_batch, param_specs,
                                  set_activation_mesh, shard_if_divisible)
from repro.utils.tree import map_with_path, path_str


class FakeMesh:
    """Duck-typed mesh with a .shape mapping (enough for the rules)."""

    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_shard_if_divisible():
    assert shard_if_divisible(256, "model", MESH) == "model"
    assert shard_if_divisible(50280, "model", MESH) is None   # mamba2 vocab
    assert shard_if_divisible(10, None, MESH) is None
    assert shard_if_divisible(32, ("pod", "data"), MESH_MP) == ("pod", "data")


def _spec_map(cfg, role="server"):
    params = jax.eval_shape(
        lambda: Transformer.init(jax.random.PRNGKey(0), cfg))
    specs = param_specs(params, MESH, role)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    return {path_str(kp): s for kp, s in flat}


def test_attention_weights_fsdp_tp_sharded():
    cfg = smoke_config("phi3-mini-3.8b").with_(
        d_model=256, d_ff=512, vocab=512)
    m = _spec_map(cfg)
    # stacked blocks: leading layer dim replicated, then (data, model)
    assert m["blocks/attn/wq"] == P(None, "data", "model")
    assert m["blocks/attn/wo"] == P(None, "model", "data")
    assert m["blocks/ffn/w_down"] == P(None, "model", "data")
    assert m["embed/table"] == P("model", "data")
    # norms replicated
    assert all(a is None for a in m["blocks/norm_attn/scale"])


def test_client_role_moves_data_axis_to_cohort():
    cfg = smoke_config("phi3-mini-3.8b")
    params = jax.eval_shape(
        lambda: Transformer.init(jax.random.PRNGKey(0), cfg))
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((16,) + l.shape, l.dtype), params)
    specs = param_specs(stacked, MESH, "client")
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    m = {path_str(kp): s for kp, s in flat}
    # cohort dim gets 'data'; the FSDP 'data' inside the rule is dropped
    assert m["blocks/attn/wq"][0] == "data"
    assert "data" not in m["blocks/attn/wq"][1:]


def test_moe_expert_vs_ffn_mode():
    from repro.configs.registry import get_config
    # olmoe full config: 64 experts shard over the 16-way model axis
    m = _spec_map(get_config("olmoe-1b-7b"))
    assert m["blocks/moe/w_gate"][1] == "model"
    # smoke config: 4 experts on 16-way -> divisibility guard drops it
    m_smoke = _spec_map(smoke_config("olmoe-1b-7b"))
    assert m_smoke["blocks/moe/w_gate"][1] is None
    # grok (8 experts, shard_mode='ffn'): expert dim unsharded, f on model
    params = jax.eval_shape(lambda: Transformer.init(
        jax.random.PRNGKey(0), get_config("grok-1-314b")))
    specs = param_specs(params, MESH, "server", moe_shard_mode="ffn")
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    m2 = {path_str(kp): s for kp, s in flat}
    assert m2["blocks/moe/w_gate"][1] is None
    assert m2["blocks/moe/w_gate"][3] == "model"


def test_optimizer_state_inherits_param_specs():
    """Adam m/v mirror the param tree; suffix rules must catch them."""
    from repro.core.protocol import init_entity
    from repro.optim import adam
    cfg = smoke_config("phi3-mini-3.8b")
    ent = jax.eval_shape(lambda: init_entity(
        Transformer.init(jax.random.PRNGKey(0), cfg), adam(1e-3)))
    specs = param_specs(ent, MESH, "server")
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    m = {path_str(kp): s for kp, s in flat}
    assert m["opt_state/m/blocks/attn/wq"] == m["params/blocks/attn/wq"]
    assert m["opt_state/v/embed/table"] == m["params/embed/table"]


def test_constrain_batch_noop_without_mesh():
    set_activation_mesh(None)
    x = jnp.ones((4, 8, 16))
    assert constrain_batch(x) is x


def test_vocab_padding():
    cfg = smoke_config("mamba2-2.7b").with_(vocab=50280)
    assert cfg.vocab_padded % 128 == 0
    assert cfg.vocab_padded >= cfg.vocab
    cfg2 = smoke_config("phi3-mini-3.8b").with_(vocab=32064)
    assert cfg2.vocab_padded == 32128
