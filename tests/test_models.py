"""Model-substrate behaviour: decode parity, masking semantics, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.configs.base import AttnConfig
from repro.models.attention import attend_full, attn_init
from repro.models.encdec import EncDec
from repro.models.moe import moe_apply, moe_init
from repro.models.transformer import Transformer

PARITY_ARCHS = ["phi3-mini-3.8b", "gemma2-2b", "glm4-9b", "mamba2-2.7b",
                "zamba2-1.2b", "pixtral-12b", "moonshot-v1-16b-a3b",
                "grok-1-314b", "olmoe-1b-7b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_teacher_forcing(arch):
    """Step-by-step decode == full forward (MoE: high capacity factor so
    no tokens drop — capacity dropping is batch-dependent by design)."""
    cfg = smoke_config(arch)
    if cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = Transformer.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    pe = (jnp.ones((B, cfg.n_patch_tokens, cfg.d_model)) * 0.01
          if cfg.family == "vlm" else None)
    full, _ = Transformer.forward(params, cfg, toks, pe)
    state = Transformer.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        lg, state = Transformer.decode_step(params, cfg, toks[:, t:t + 1], state)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    if cfg.family == "vlm":
        # decode path has no patch prefix; compare text-only region
        full_t, _ = Transformer.forward(params, cfg, toks, None)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full_t),
                                   atol=1e-3)
    else:
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   atol=1e-3)


def test_whisper_decode_parity():
    cfg = smoke_config("whisper-base")
    params = EncDec.init(jax.random.PRNGKey(0), cfg)
    B, T, S = 2, 12, 6
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.1
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full = EncDec.forward(params, cfg, frames, toks)
    state = EncDec.init_decode_state(params, cfg, frames, seq_len=S)
    outs = []
    for t in range(S):
        lg, state = EncDec.decode_step(params, cfg, toks[:, t:t + 1], state)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-3)


def test_causal_mask_blocks_future():
    """Changing a future token must not change earlier logits."""
    cfg = smoke_config("phi3-mini-3.8b")
    params = Transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, cfg.vocab)
    l1, _ = Transformer.forward(params, cfg, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab)
    l2, _ = Transformer.forward(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                               atol=1e-5)
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) > 1e-6


def test_sliding_window_limits_receptive_field():
    """With window w, position t ignores tokens < t - w + 1."""
    cfg = smoke_config("glm4-9b").with_(
        attn=AttnConfig(window=4, pattern="local"))
    params = Transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    l1, _ = Transformer.forward(params, cfg, toks)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 3) % cfg.vocab)
    l2, _ = Transformer.forward(params, cfg, toks2)
    # position 11 is > 4 steps after 0 in every (windowed) layer; with
    # 2 stacked layers information can still travel 2*(w-1) — use last pos
    # far enough: receptive field = n_layers*(w-1) = 6 < 11.
    np.testing.assert_allclose(np.asarray(l1[:, 11]), np.asarray(l2[:, 11]),
                               atol=1e-4)


def test_gemma2_softcap_bounds_logits():
    cfg = smoke_config("gemma2-2b")
    params = Transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, _ = Transformer.forward(params, cfg, toks)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.attn.final_softcap + 1e-3


def test_moe_capacity_drops_overflow(rng):
    """With capacity_factor→0 every token drops: output ≈ 0 (plus shared)."""
    cfg = smoke_config("olmoe-1b-7b")
    mcfg = dataclasses.replace(cfg.moe, capacity_factor=1e-9)
    params = moe_init(jax.random.PRNGKey(0), 16, mcfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    y, m = moe_apply(params, mcfg, x)
    # capacity 1 minimum -> at most E tokens survive; most output rows zero
    zero_rows = int(jnp.sum(jnp.all(y == 0, axis=-1)))
    assert zero_rows >= 1


def test_moe_load_metrics(rng):
    cfg = smoke_config("olmoe-1b-7b")
    params = moe_init(jax.random.PRNGKey(0), 16, cfg.moe, jnp.float32)
    x = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    y, m = moe_apply(params, cfg.moe, x)
    assert y.shape == x.shape
    np.testing.assert_allclose(float(jnp.sum(m["load"])), 1.0, atol=1e-5)
    assert float(m["aux_loss"]) >= 1.0 - 1e-5  # >= 1 by Cauchy-Schwarz


def test_gqa_broadcast_matches_repeated_kv(rng):
    """GQA attention == MHA with explicitly repeated KV heads."""
    cfg = smoke_config("glm4-9b")              # kv=2, heads=4
    params = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    out, (k, v) = attend_full(params, cfg, x, pos, None)

    cfg_mha = cfg.with_(n_kv_heads=cfg.n_heads)
    rep = cfg.n_heads // cfg.n_kv_heads
    params_mha = dict(params)
    params_mha["wk"] = jnp.concatenate([
        params["wk"].reshape(cfg.d_model, cfg.n_kv_heads, cfg.hd)
        .repeat(rep, axis=1).reshape(cfg.d_model, -1)], axis=-1)
    params_mha["wv"] = jnp.concatenate([
        params["wv"].reshape(cfg.d_model, cfg.n_kv_heads, cfg.hd)
        .repeat(rep, axis=1).reshape(cfg.d_model, -1)], axis=-1)
    out2, _ = attend_full(params_mha, cfg_mha, x, pos, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-5)
