"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the real
single CPU device; only launch/dryrun.py forces 512 host devices."""
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "kernels: kernel-equivalence, shard-local resample, and Pallas "
        "property suites (the CI 'kernels' leg runs `-m kernels` under 8 "
        "forced host devices)")
    config.addinivalue_line(
        "markers",
        "resilience: fault-injection and crash/resume suites (the CI "
        "'resilience' leg runs `-m resilience` under 8 forced host "
        "devices and uploads BENCH_resilience.json)")
    config.addinivalue_line(
        "markers",
        "serving: continuous-batching serve runtime suite (the CI "
        "'serving' leg runs `-m serving` under 8 forced host devices "
        "and uploads BENCH_serving.json)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
