"""Quickstart: one CycleSL round, spelled out (paper Algorithm 1).

Runs on CPU in ~a minute.  Shows the API at its lowest level:
SplitTask -> EntityStates -> cyclesl_round, and prints what each phase
did.  The same round is registered declaratively in ``repro.api`` as

    RoundProgram("cyclesfl", ExtractFeatures -> ServerUpdate(cycle)
                 -> FeatureGradients(updated server) -> ClientUpdate
                 -> Commit(average))

and full experiments run through the single driver::

    from repro.api import Engine, ExperimentConfig
    Engine(ExperimentConfig(algo="cyclesfl", rounds=100)).run()

(see ``examples/cross_device_federated.py``).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import get_program
from repro.core.cyclesl import CycleConfig, cyclesl_round
from repro.core.protocol import broadcast_entity, init_entity
from repro.core.split import make_stage_task
from repro.models.cnn import femnist_cnn
from repro.optim import adam


def main():
    # 1. a split model: LEAF-style CNN cut in the middle (client: conv
    #    stages, server: dense head) — the paper's FEMNIST setup.
    model = femnist_cnn(n_classes=10, width=8)
    task = make_stage_task(model, cut=2, kind="xent")
    print(f"task: {task.name}")

    # 2. entities: ONE server, a cohort of 4 clients, each with its own
    #    Adam state (the server task is standalone — paper §3.1).
    opt_server, opt_client = adam(1e-3), adam(1e-3)
    server = init_entity(task.init_server(jax.random.PRNGKey(0)), opt_server)
    clients = broadcast_entity(
        init_entity(task.init_client(jax.random.PRNGKey(1)), opt_client), 4)

    # 3. per-client non-iid batches (each client sees 2-3 digit classes)
    rng = np.random.default_rng(0)
    xs, ys = [], []
    for c in range(4):
        classes = rng.choice(10, size=3, replace=False)
        y = rng.choice(classes, size=16)
        x = rng.normal(size=(16, 28, 28, 1)) * 0.5 + y[:, None, None, None] / 10
        xs.append(x)
        ys.append(y)
    xs = jnp.asarray(np.stack(xs), jnp.float32)
    ys = jnp.asarray(np.stack(ys))

    # 4. one CycleSL round: client features -> pooled feature dataset ->
    #    E server epochs on resampled batches -> frozen-server gradients
    #    -> client updates.
    for rnd in range(5):
        server, clients, metrics = cyclesl_round(
            task, server, clients, opt_server, opt_client, xs, ys,
            jax.random.PRNGKey(100 + rnd), CycleConfig(server_epochs=2))
        print(f"round {rnd}: server_loss={float(metrics['server_loss']):.4f} "
              f"feat_grad_norm={float(metrics['feat_grad_norm_mean']):.4f} "
              f"(server took {int(server.step)} total inner steps)")

    print("\nNote the cyclical order: the server optimized FIRST on the")
    print("resampled feature dataset; clients then received gradients from")
    print("the UPDATED, frozen server (Eq. 5) — not end-to-end backprop.")
    print("\nThe same round, as registered in repro.api:")
    for name in ("sflv1", "cyclesfl"):
        print(f"  {name:9s} = {get_program(name).describe()}")


if __name__ == "__main__":
    main()
