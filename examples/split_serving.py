"""Split serving: batched autoregressive decode with a KV/SSM cache.

Demonstrates the serve path the decode dry-run shapes lower — here on
reduced configs so it runs on CPU.  Tries one arch per cache family:
dense KV cache (gemma2 local/global ring buffers), pure SSM state
(mamba2), and the hybrid (zamba2).

  PYTHONPATH=src python examples/split_serving.py --steps 12
"""
import argparse

from repro.launch.serve import serve_decoder_only, serve_whisper
from repro.configs.registry import smoke_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    for arch in ("gemma2-2b", "mamba2-2.7b", "zamba2-1.2b"):
        cfg = smoke_config(arch)
        res = serve_decoder_only(cfg, batch=args.batch, prompt_len=4,
                                 steps=args.steps)
        toks = res.pop("tokens")
        print(f"{arch:14s} {toks.shape[1]} tokens/seq, "
              f"{res['decode_s_per_token']*1e3:.1f} ms/token "
              f"(cache family: {'ssm' if 'mamba' in arch else 'hybrid' if 'zamba' in arch else 'kv-ring'})")

    cfg = smoke_config("whisper-base")
    res = serve_whisper(cfg, batch=args.batch, steps=args.steps)
    res.pop("tokens")
    print(f"{'whisper-base':14s} enc-dec decode, "
          f"{res['decode_s_per_token']*1e3:.1f} ms/token (cross-attn cache)")


if __name__ == "__main__":
    main()
