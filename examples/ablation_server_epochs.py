"""Paper Table 5 ablation as a runnable example: server epochs E vs
heterogeneity alpha for CycleSFL on the synthetic task.

Each cell is one frozen :class:`ExperimentConfig` (the nested
``CycleConfig`` carries E) run by the shared ``repro.api.Engine`` loop.

  PYTHONPATH=src python examples/ablation_server_epochs.py --rounds 40
"""
import argparse
from dataclasses import replace

from repro.api import Engine, ExperimentConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    args = ap.parse_args()
    base = ExperimentConfig(algo="cyclesfl", task="image",
                            rounds=args.rounds, eval_every=args.rounds)
    print(f"{'alpha':>6s} {'E':>3s} {'test_loss':>10s} {'accuracy':>9s}")
    for alpha in (1.0, 0.1):
        for E in (1, 2, 4):
            cfg = replace(base, alpha=alpha).with_cycle(server_epochs=E)
            res = Engine(cfg, log=lambda *a, **k: None).run()
            h = res["history"][-1]
            print(f"{alpha:6.1f} {E:3d} {h['test_loss']:10.4f} "
                  f"{h['accuracy']:9.4f}")


if __name__ == "__main__":
    main()
