"""End-to-end driver: cross-device split learning on a synthetic non-iid
task — compares an SL baseline against its Cycle variant (paper Table 3,
miniaturized) through the unified ``repro.api`` experiment API: one
frozen :class:`ExperimentConfig` per run, swapped via ``dataclasses.replace``,
all executed by the single ``Engine.run()`` driver loop.

Trains two ~hundred-round runs on CPU (a few minutes):

  PYTHONPATH=src python examples/cross_device_federated.py \
      --baseline sflv1 --rounds 80

Pass ``--scenario`` to run both algorithms under a churny client
population (dropouts / stragglers / diurnal availability — see
``repro.scenario``), e.g.::

  ... --scenario uniform --scenario-dropout 0.2

Pass ``--guard`` (and optionally ``--faults``) to arm the resilience
runtime: in-trace NaN/spike health guards plus the recovery policies
(quarantine / retry / rollback — see ``repro.resilience``), e.g.::

  ... --guard --faults nan=0.1,persist=9 --on-nonfinite quarantine
"""
import argparse
from dataclasses import replace

from repro.api import Engine, ExperimentConfig
from repro.resilience import ResilienceConfig
from repro.scenario.profiles import ScenarioConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="sflv1",
                    choices=["psl", "sglr", "sflv1", "sflv2"])
    ap.add_argument("--rounds", type=int, default=80)
    ap.add_argument("--clients", type=int, default=80)
    ap.add_argument("--alpha", type=float, default=0.5)
    ScenarioConfig.add_arguments(ap)
    ResilienceConfig.add_arguments(ap)
    args = ap.parse_args()

    cycle_of = {"psl": "cyclepsl", "sglr": "cyclesglr",
                "sflv1": "cyclesfl", "sflv2": "cyclesfl"}
    scenario = ScenarioConfig.from_flags(args)
    resilience = ResilienceConfig.from_flags(args)
    base_cfg = ExperimentConfig(
        algo=args.baseline, task="image", rounds=args.rounds,
        n_clients=args.clients, alpha=args.alpha, attendance=0.05,
        eval_every=max(10, args.rounds // 8), scenario=scenario,
        resilience=resilience)
    results = {}
    for algo in (args.baseline, cycle_of[args.baseline]):
        print(f"\n=== {algo} ===")
        res = Engine(replace(base_cfg, algo=algo)).run()
        results[algo] = res["history"][-1]
        if scenario.churns and "telemetry" in res:
            t = res["telemetry"]
            print(f"[churn] live_cohort_mean={t['live_cohort_mean']:.1f} "
                  f"dropped={t['dropped_total']} "
                  f"(hazard={t['drop_hazard_total']}, "
                  f"deadline={t['drop_deadline_total']}) "
                  f"max_lag={t['max_drawn_lag']}")
        if "resilience" in res:
            r = res["resilience"]
            print(f"[resilience] faulted_rounds={r['faulted_rounds']} "
                  f"retries={r['retries']} rollbacks={r['rollbacks']} "
                  f"quarantined={r['quarantined_clients']} "
                  f"ckpt_corruptions={r['ckpt_corruptions']}")

    base, cyc = args.baseline, cycle_of[args.baseline]
    print("\n=== summary ===")
    for k in (base, cyc):
        h = results[k]
        print(f"{k:10s} test_loss={h['test_loss']:.4f} "
              f"accuracy={h.get('accuracy', float('nan')):.4f}")
    better = results[cyc].get("accuracy", 0) >= results[base].get("accuracy", 0)
    print(f"\ncycle variant better-or-equal: {better} "
          f"(paper Table 3 claim, miniaturized)")


if __name__ == "__main__":
    main()
